//! Cross-engine KV sharing: a host-side shared prefix-segment store.
//!
//! The per-engine radix cache ([`crate::engine::kvcache`]) collapses a GRPO
//! group's G prefills into 1 and resumes template-sharing prompts from their
//! longest locally cached prefix — but it stops at the engine boundary: with
//! N engines, a few-shot template shared across groups is still prefilled
//! once *per engine*. This module is the missing plane (the decoupled KV/data
//! layer AsyncFlow and Laminar argue for): a host-resident, content-addressed
//! store of block-granular KV segments shared by every engine in the
//! coordinator, turning N per-engine caches into one logical cache.
//!
//! # Shard topology
//!
//! The store is `S` independent `Shard`s ([`shard`]; `engine.store_shards` in
//! the config; default 1), each behind its own `Mutex` and owning a disjoint
//! slice of the block budget. A *chain* — every block entry of one published
//! prefix — lives entirely in one shard: the facade range-partitions on the
//! hash of the chain's **first block** ([`hash`]), so two prompts sharing a
//! template land in the same shard (and dedupe there), while unrelated
//! templates spread across shards and never contend on one lock. Every
//! operation therefore locks exactly one shard; only `set_version`,
//! `stats()` and the gauges touch all of them (sequentially — never nested,
//! so no lock-order concerns). With `S = 1` the store is bit-identical to
//! the previous single-`Mutex<StoreCore>` design.
//!
//! # Heap laziness
//!
//! Each shard replaces the old O(n) eviction scan with a lazily-invalidated
//! min-heap of `(policy key, entry key)` candidates: transitions *into*
//! evictability push, nothing ever removes — pops discard entries that have
//! since been evicted, re-leased or re-keyed, and a size-bounded compaction
//! keeps the heap O(live entries) under touch-heavy workloads. Ticks are
//! monotone and never reused, so a stale entry can never masquerade as
//! current. The pop order over current keys equals the old scan's
//! `min_by_key` order, which is what makes `shards = 1` victim-for-victim
//! identical (enforced by the differential proptest in [`shard`]).
//!
//! # Invariants the tests enforce
//!
//! * **Capacity**: a shard never holds more entries than its slice; the
//!   facade's `live_blocks() <= capacity_blocks()` at all times, including
//!   under multi-threaded contention (`tests/store_stress.rs`).
//! * **Lease pinning**: a fetched chain's entries cannot be evicted while
//!   any lease pins them; re-fetching a leased prefix is bit-exact.
//! * **Bit-exact fetch**: fetched rows always equal what a local prefill
//!   would have computed (prefix-dependent row oracle in the proptests).
//! * **Heap covering**: every currently evictable entry has a live heap
//!   entry carrying its current policy key (`Shard::check`).
//! * **Version gating**: a real params bump flushes every shard in lockstep
//!   and bumps the lease epochs; stale publishes/fetches/releases are
//!   rejected or ignored.
//!
//! Structure: [`segments`] — the entry/result types; [`shard`] — the
//! per-shard map, heap eviction and residency probe; [`SharedKvStore`] — the
//! sharded facade engine worker threads share via `Arc`
//! ([`crate::coordinator::EngineMsg::AttachStore`]); [`stats`] — per-shard
//! counters the facade aggregates.
//!
//! Engine integration (see `engine::admit_chunked`): on admission, when the
//! local radix match is short, the engine fetches the longest published
//! prefix from the store, *imports* it into its local cache
//! (`PrefixCache::insert_prefix`), and proceeds exactly as if the prefix had
//! always been local — so restore, chunk planning, token accounting and the
//! bit-exactness story are unchanged, and the import shows up as
//! `cross_engine_hits` / `cross_engine_tokens` in
//! [`crate::engine::EngineStats`]. Completed prefixes are published back
//! once per admission,
//! bounded by a per-engine, per-sync-interval publish budget
//! (`engine.store_publish`) so a churny workload cannot thrash the store.
//! The coordinator additionally consults [`SharedKvStore::residency_blocks`]
//! when routing groups: store residency makes a spill cheap (the target
//! imports instead of recomputing), so the router can trade backlog slack
//! against actual warmth instead of hashing blindly.
//!
//! Consistency: segments are functions of the policy weights. The store is
//! bound to a params version ([`SharedKvStore::set_version`], called by
//! every engine inside `set_weights`): a real version bump flushes every
//! shard and bumps the lease epochs (stale releases are ignored); publishes
//! and fetches carrying a mismatched version are rejected, so KV computed
//! under old weights can never cross into a new iteration.

pub mod hash;
pub mod segments;
pub mod shard;
pub mod stats;

pub use segments::Publish;
pub use stats::StoreStats;

use crate::check::sync::{lock_or_poison, Mutex, MutexGuard};
use crate::engine::kvcache::EvictPolicy;
use shard::Shard;

/// Store sizing/eviction knobs (validated by `config::Config`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreCfg {
    /// Tokens per segment block — the engines' `cache_block`, so store keys
    /// land on the same boundaries the engines publish and match at.
    pub block_tokens: usize,
    /// Capacity in block entries, split across the shards.
    pub capacity_blocks: usize,
    pub policy: EvictPolicy,
    /// Independent hash-range shards (>= 1); 1 = the single-mutex store.
    pub shards: usize,
}

/// Ref-counted pin on the segments a fetch matched; held by the importing
/// request until retirement, released through [`SharedKvStore::release`].
/// Epoch-tagged: releases that outlive a version flush are ignored. Not
/// `Clone` — the type system enforces at most one release per acquire, which
/// is what keeps the refcounts non-negative by construction. A chain lives
/// in exactly one shard, so the lease remembers which.
#[derive(Debug)]
pub struct StoreLease {
    keys: Vec<u64>,
    shard: usize,
    epoch: u64,
}

/// A successful cross-engine fetch: the longest published prefix of the
/// query, ready to import into a local [`crate::engine::PrefixCache`].
#[derive(Debug)]
pub struct Fetched {
    /// Tokens covered (block-granular; may equal the full prompt).
    pub len: usize,
    /// Token-major KV rows for `[0, len)`.
    pub rows: Vec<f32>,
    /// Terminal logits when a complete published prompt ends at `len`.
    pub logits: Option<Vec<f32>>,
    pub lease: StoreLease,
}

/// The shared store: one instance per coordinator, `Arc`-shared with every
/// engine worker thread. Each call locks exactly one shard (chosen by the
/// query's first-block hash) and copies rows in or out under that lock, so
/// no reader ever observes an evicted segment.
#[derive(Debug)]
pub struct SharedKvStore {
    shards: Vec<Mutex<Shard>>,
    block_tokens: usize,
}

impl SharedKvStore {
    pub fn new(cfg: StoreCfg) -> SharedKvStore {
        let s = cfg.shards.max(1);
        assert!(
            cfg.capacity_blocks >= s,
            "store capacity {} cannot give {s} shards a nonzero slice",
            cfg.capacity_blocks
        );
        let shards = (0..s)
            .map(|i| {
                // Shard i's capacity slice; slices sum to capacity_blocks.
                let cap = cfg.capacity_blocks / s + usize::from(i < cfg.capacity_blocks % s);
                Mutex::new(Shard::new(cfg.block_tokens, cap, cfg.policy))
            })
            .collect();
        SharedKvStore { shards, block_tokens: cfg.block_tokens }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn lock(&self, idx: usize) -> MutexGuard<'_, Shard> {
        // Poisoning recovery: a publisher that panicked mid-publish leaves
        // the shard consistent (all mutations happen after validation), so
        // other threads keep going instead of cascade-panicking.
        lock_or_poison(&self.shards[idx])
    }

    /// Deliberately acquire shards `a` then `b` in *that* textual order —
    /// exists only so the model-check suite can demonstrate that the
    /// checker catches an inverted-lock-order deadlock. Never called by
    /// production code.
    #[cfg(any(test, feature = "pa_modelcheck"))]
    pub fn lock_pair_in_order(&self, a: usize, b: usize) -> usize {
        let ga = self.lock(a);
        let gb = self.lock(b);
        ga.live_blocks() + gb.live_blocks()
    }

    /// Shard owning `tokens`' chain: range partition on the first block's
    /// hash. The whole chain shares the first block's key prefix-dependently
    /// — every deeper key extends the same first block — so publish, fetch
    /// and residency for one prompt family always land on one shard.
    fn shard_for(&self, tokens: &[u32]) -> usize {
        if self.shards.len() == 1 || tokens.is_empty() {
            return 0;
        }
        let head = &tokens[..tokens.len().min(self.block_tokens)];
        let key = hash::hash_prefix(head);
        // Multiply-shift range partition of the 64-bit key space.
        ((key as u128 * self.shards.len() as u128) >> 64) as usize
    }

    /// Bind the store to a params version; flushes every shard on a real
    /// bump (lockstep — shards never disagree about the version). Engines
    /// call this from `set_weights`, so the first engine to install a new
    /// version invalidates every stale segment for all of them.
    pub fn set_version(&self, version: u64) -> bool {
        let mut flushed = false;
        for i in 0..self.shards.len() {
            flushed |= self.lock(i).set_version(version);
        }
        flushed
    }

    /// Publish a completed prefix (KV rows + optional terminal logits)
    /// computed under `version`, evicting unleased segments to make room.
    /// Idempotent per block; see [`Publish`].
    pub fn publish(
        &self,
        tokens: &[u32],
        rows: &[f32],
        logits: Option<&[f32]>,
        version: u64,
    ) -> Publish {
        let idx = self.shard_for(tokens);
        self.lock(idx).publish(tokens, rows, logits, version, true)
    }

    /// Publish only the *block-aligned head* of a completed prefix — the
    /// form engines (and their mocks/benches) use. An unaligned tail block
    /// is keyed by the whole prompt's hash, fetchable only by a byte-exact
    /// duplicate on another engine, so sharing it is dead weight; terminal
    /// logits therefore attach only when the prefix is already aligned.
    /// Prefixes shorter than one block have nothing shareable and return
    /// [`Publish::Duplicate`]. `allow_evict = false` publishes into free
    /// capacity and dedup-refreshes only — the budget-exhausted engine mode.
    pub fn publish_aligned(
        &self,
        tokens: &[u32],
        rows: &[f32],
        logits: Option<&[f32]>,
        version: u64,
        allow_evict: bool,
    ) -> Publish {
        let aligned = tokens.len() / self.block_tokens * self.block_tokens;
        if aligned == 0 {
            return Publish::Duplicate;
        }
        let idx = self.shard_for(tokens);
        if aligned == tokens.len() {
            self.lock(idx).publish(tokens, rows, logits, version, allow_evict)
        } else {
            let re = rows.len() / tokens.len();
            self.lock(idx)
                .publish(&tokens[..aligned], &rows[..aligned * re], None, version, allow_evict)
        }
    }

    /// Longest published prefix of `tokens` covering strictly more than
    /// `min_len` tokens, under `version`. Acquires a lease on the matched
    /// segments.
    pub fn fetch_longest(&self, tokens: &[u32], min_len: usize, version: u64) -> Option<Fetched> {
        let idx = self.shard_for(tokens);
        let mut shard = self.lock(idx);
        let f = shard.fetch_longest(tokens, min_len, version)?;
        let epoch = shard.epoch;
        Some(Fetched {
            len: f.len,
            rows: f.rows,
            logits: f.logits,
            lease: StoreLease { keys: f.keys, shard: idx, epoch },
        })
    }

    /// Tokens of `tokens` covered by resident segments (block-granular) —
    /// the coordinator's residency probe for routing decisions. Non-mutating
    /// and lease-free: no LRU refresh, no fetch counters, so probing a
    /// candidate prompt cannot perturb eviction order or hit rates.
    pub fn residency_blocks(&self, tokens: &[u32]) -> usize {
        let idx = self.shard_for(tokens);
        self.lock(idx).residency(tokens)
    }

    /// Release a fetch lease (importing request retired). Stale leases from
    /// before a version flush are ignored.
    pub fn release(&self, lease: StoreLease) {
        let mut shard = self.lock(lease.shard);
        if lease.epoch == shard.epoch {
            shard.release(&lease.keys);
        }
    }

    /// Aggregate counters across shards.
    pub fn stats(&self) -> StoreStats {
        let mut total = StoreStats::default();
        for i in 0..self.shards.len() {
            total.absorb(&self.lock(i).stats);
        }
        total
    }

    pub fn live_blocks(&self) -> usize {
        (0..self.shards.len()).map(|i| self.lock(i).live_blocks()).sum()
    }

    pub fn leased_blocks(&self) -> usize {
        (0..self.shards.len()).map(|i| self.lock(i).leased_blocks()).sum()
    }

    pub fn capacity_blocks(&self) -> usize {
        (0..self.shards.len()).map(|i| self.lock(i).capacity()).sum()
    }

    /// Structural invariants (for the proptests): every shard's map, heap
    /// covering and capacity slice.
    pub fn check(&self) -> Result<(), String> {
        for i in 0..self.shards.len() {
            self.lock(i).check().map_err(|e| format!("shard {i}: {e}"))?;
        }
        Ok(())
    }

    /// Lease epoch (shards advance in lockstep; any shard's value is the
    /// store's). Test-only visibility for the lease-validity proptests.
    #[cfg(test)]
    pub(crate) fn current_epoch(&self) -> u64 {
        self.lock(0).epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    const RE: usize = 3; // row elems

    fn store(capacity: usize, bt: usize) -> SharedKvStore {
        store_sharded(capacity, bt, 1)
    }

    fn store_sharded(capacity: usize, bt: usize, shards: usize) -> SharedKvStore {
        SharedKvStore::new(StoreCfg {
            block_tokens: bt,
            capacity_blocks: capacity,
            policy: EvictPolicy::Lru,
            shards,
        })
    }

    /// Deterministic prefix-dependent rows, mirroring real KV: row p depends
    /// on tokens[..=p] only — so any correctly assembled prefix import is
    /// bit-identical to what a local prefill would have computed.
    fn rows_for(seq: &[u32]) -> Vec<f32> {
        let mut acc = 11u64;
        let mut out = Vec::with_capacity(seq.len() * RE);
        for &t in seq {
            acc = acc.wrapping_mul(2862933555777941757).wrapping_add(u64::from(t) + 1);
            for e in 0..RE {
                out.push(((acc >> (e * 7 % 50)) & 0xFF) as f32);
            }
        }
        out
    }

    fn logits_for(seq: &[u32]) -> Vec<f32> {
        vec![seq.iter().sum::<u32>() as f32, seq.len() as f32]
    }

    #[test]
    fn publish_fetch_roundtrip_block_granular() {
        let s = store(16, 4);
        let a: Vec<u32> = (0..10).collect(); // 2 full blocks + 2-token tail
        assert!(matches!(
            s.publish(&a, &rows_for(&a), Some(&logits_for(&a)), 7),
            Publish::StaleVersion
        ));
        s.set_version(7);
        assert_eq!(s.publish(&a, &rows_for(&a), Some(&logits_for(&a)), 7), Publish::Stored { blocks: 3, evicted: 0 });
        assert_eq!(s.live_blocks(), 3);

        // Exact query: full coverage including terminal logits.
        let f = s.fetch_longest(&a, 0, 7).expect("full hit");
        assert_eq!(f.len, 10);
        assert_eq!(f.rows, rows_for(&a));
        assert_eq!(f.logits.as_deref(), Some(&logits_for(&a)[..]));
        assert_eq!(s.leased_blocks(), 3);
        s.release(f.lease);
        assert_eq!(s.leased_blocks(), 0);

        // A different suffix shares the template at block granularity: the
        // tail block diverges, so coverage is the aligned 8 tokens.
        let b: Vec<u32> = [&a[..8], &[90, 91, 92][..]].concat();
        let f = s.fetch_longest(&b, 0, 7).expect("template hit");
        assert_eq!(f.len, 8);
        assert_eq!(f.rows, rows_for(&a[..8]));
        assert!(f.logits.is_none(), "partial coverage has no terminal logits");
        s.release(f.lease);

        // min_len at or above coverage is a miss (nothing new to import).
        assert!(s.fetch_longest(&b, 8, 7).is_none());
        assert!(s.fetch_longest(&[55, 56], 0, 7).is_none(), "cold prefix misses");
        s.check().unwrap();
    }

    #[test]
    fn republication_dedupes_and_upgrades_logits() {
        let s = store(16, 4);
        s.set_version(1);
        let a: Vec<u32> = (0..8).collect();
        // Intermediate (chunk-boundary) publication without logits...
        assert_eq!(
            s.publish(&a[..4], &rows_for(&a[..4]), None, 1),
            Publish::Stored { blocks: 1, evicted: 0 }
        );
        // ...then the full prompt: only the new block is stored, and the
        // terminal boundary gains logits.
        assert_eq!(
            s.publish(&a, &rows_for(&a), Some(&logits_for(&a)), 1),
            Publish::Stored { blocks: 1, evicted: 0 }
        );
        assert_eq!(s.publish(&a, &rows_for(&a), Some(&logits_for(&a)), 1), Publish::Duplicate);
        assert_eq!(s.live_blocks(), 2);
        let f = s.fetch_longest(&a, 0, 1).unwrap();
        assert_eq!(f.logits.as_deref(), Some(&logits_for(&a)[..]));
        s.release(f.lease);
    }

    #[test]
    fn version_bump_flushes_and_invalidates_leases() {
        let s = store(8, 2);
        s.set_version(1);
        let a = vec![1, 2, 3, 4];
        s.publish(&a, &rows_for(&a), Some(&logits_for(&a)), 1);
        let f = s.fetch_longest(&a, 0, 1).unwrap();
        assert!(s.set_version(2), "real bump flushes");
        assert_eq!(s.live_blocks(), 0);
        assert!(s.fetch_longest(&a, 0, 2).is_none());
        // Stale-version traffic is rejected outright.
        assert!(matches!(s.publish(&a, &rows_for(&a), None, 1), Publish::StaleVersion));
        // Stale lease release is ignored, and must not corrupt the store.
        s.release(f.lease);
        assert!(!s.set_version(2), "re-announcing the same version keeps the store");
        s.publish(&a, &rows_for(&a), None, 2);
        assert_eq!(s.live_blocks(), 2);
        s.check().unwrap();
    }

    #[test]
    fn leases_pin_against_eviction_and_capacity_holds() {
        let s = store(2, 2);
        s.set_version(1);
        let hot = vec![1, 1];
        let cold = vec![2, 2];
        s.publish(&hot, &rows_for(&hot), Some(&logits_for(&hot)), 1);
        s.publish(&cold, &rows_for(&cold), Some(&logits_for(&cold)), 1);
        let f = s.fetch_longest(&hot, 0, 1).expect("hot resident");
        // A third publish must evict the unleased cold entry, not hot.
        let c = vec![3, 3];
        assert_eq!(s.publish(&c, &rows_for(&c), None, 1), Publish::Stored { blocks: 1, evicted: 1 });
        assert_eq!(s.live_blocks(), 2);
        assert!(s.fetch_longest(&cold, 0, 1).is_none(), "cold evicted");
        let f2 = s.fetch_longest(&hot, 0, 1).expect("leased entry survived");
        assert_eq!(f2.rows, rows_for(&hot));
        // With both residents leased, a further publish drops.
        let f3 = s.fetch_longest(&c, 0, 1).unwrap();
        let d = vec![4, 4];
        assert_eq!(s.publish(&d, &rows_for(&d), None, 1), Publish::Dropped);
        assert_eq!(s.stats().publish_drops, 1);
        for l in [f, f2, f3] {
            s.release(l.lease);
        }
        s.check().unwrap();
    }

    #[test]
    fn publish_never_evicts_its_own_chain() {
        // Capacity 2, three 1-token blocks: the third block finds only the
        // first two (just stored, part of this very chain) as candidates —
        // evicting them would orphan the chain, so the publish must drop
        // the tail block instead and leave a fetchable 2-block prefix.
        let s = store(2, 1);
        s.set_version(1);
        let p = vec![1, 2, 3];
        assert_eq!(
            s.publish(&p, &rows_for(&p), Some(&logits_for(&p)), 1),
            Publish::Stored { blocks: 2, evicted: 0 }
        );
        assert_eq!(s.stats().publish_drops, 1);
        assert_eq!(s.stats().evictions, 0, "own chain must never be the victim");
        let f = s.fetch_longest(&p, 0, 1).expect("chain prefix stays fetchable");
        assert_eq!(f.len, 2);
        assert_eq!(f.rows, rows_for(&p[..2]));
        s.release(f.lease);
        s.check().unwrap();
    }

    #[test]
    fn publish_aligned_shares_heads_not_tails() {
        let s = store(16, 4);
        s.set_version(1);
        let a: Vec<u32> = (0..10).collect(); // 2 blocks + 2-token tail
        assert_eq!(
            s.publish_aligned(&a, &rows_for(&a), Some(&logits_for(&a)), 1, true),
            Publish::Stored { blocks: 2, evicted: 0 },
            "only the aligned head is stored"
        );
        assert_eq!(s.live_blocks(), 2);
        let f = s.fetch_longest(&a, 0, 1).expect("head fetchable");
        assert_eq!(f.len, 8);
        assert!(f.logits.is_none(), "tail logits must not leak onto the head");
        s.release(f.lease);
        // Sub-block prefixes have nothing shareable.
        assert_eq!(s.publish_aligned(&a[..3], &rows_for(&a[..3]), None, 1, true), Publish::Duplicate);
        // Aligned prefixes publish in full, logits included.
        let b: Vec<u32> = (20..28).collect();
        assert_eq!(
            s.publish_aligned(&b, &rows_for(&b), Some(&logits_for(&b)), 1, true),
            Publish::Stored { blocks: 2, evicted: 0 }
        );
        let f = s.fetch_longest(&b, 0, 1).unwrap();
        assert_eq!(f.logits.as_deref(), Some(&logits_for(&b)[..]));
        s.release(f.lease);
        s.check().unwrap();
    }

    #[test]
    fn chains_stay_shard_local_and_capacity_splits() {
        let s = store_sharded(17, 2, 4);
        // Slices sum to the configured capacity (17 = 5 + 4 + 4 + 4).
        assert_eq!(s.capacity_blocks(), 17);
        assert_eq!(s.shard_count(), 4);
        s.set_version(1);
        // Many distinct templates: every chain fetches back intact (its
        // blocks were not scattered across shards), and at least two shards
        // end up populated (the partition actually spreads).
        let mut populated = std::collections::HashSet::new();
        for t in 0..12u32 {
            let p: Vec<u32> = (0..6).map(|i| t * 37 + i).collect();
            s.publish(&p, &rows_for(&p), Some(&logits_for(&p)), 1);
            populated.insert(s.shard_for(&p));
            if let Some(f) = s.fetch_longest(&p, 0, 1) {
                assert_eq!(f.rows, rows_for(&p[..f.len]), "chain torn across shards");
                s.release(f.lease);
            }
        }
        assert!(populated.len() >= 2, "partition never spread: {populated:?}");
        // Same template, different suffixes: one shard, so dedup still works.
        let tpl: Vec<u32> = (100..104).collect();
        let p1: Vec<u32> = [&tpl[..], &[1, 1][..]].concat();
        let p2: Vec<u32> = [&tpl[..], &[2, 2][..]].concat();
        assert_eq!(s.shard_for(&p1), s.shard_for(&p2));
        s.check().unwrap();
    }

    /// The acceptance invariants under random cross-engine traffic: publishes
    /// and fetches over template-sharing prompts, random lease retirement,
    /// eviction pressure and version bumps — at arbitrary shard counts.
    /// After every op:
    /// * every fetch is bit-exact against the prefix-dependent row oracle and
    ///   covers more than `min_len`;
    /// * the block budget is respected;
    /// * every outstanding (epoch-valid) lease's segments are still resident
    ///   (leases pin; refcounts can never go negative — release is
    ///   move-consuming);
    /// * after releasing everything and bumping the version, the store drains
    ///   to empty.
    #[test]
    fn prop_store_traffic_invariants() {
        prop::quick(
            "shared store: cross-engine traffic invariants",
            |rng: &mut Pcg64, size| {
                let bt = rng.range(1, 5);
                let shards = rng.range(1, 5);
                let capacity = rng.range(shards.max(2), 24 + shards);
                let n_templates = rng.range(1, 4);
                let templates: Vec<Vec<u32>> = (0..n_templates)
                    .map(|_| (0..rng.range(1, 10)).map(|_| rng.range(0, 5) as u32).collect())
                    .collect();
                let ops: Vec<(u64, Vec<u32>)> = (0..size.scaled(50))
                    .map(|_| {
                        let t = &templates[rng.range(0, n_templates)];
                        let mut p = t.clone();
                        p.extend((0..rng.range(0, 5)).map(|_| rng.range(0, 5) as u32));
                        (rng.next_u64(), p)
                    })
                    .collect();
                (bt, capacity, shards, ops)
            },
            |(bt, capacity, shards, ops)| {
                let s = SharedKvStore::new(StoreCfg {
                    block_tokens: *bt,
                    capacity_blocks: *capacity,
                    policy: EvictPolicy::Lru,
                    shards: *shards,
                });
                let mut version = 1u64;
                s.set_version(version);
                let mut leases: Vec<StoreLease> = Vec::new();
                for (op, prompt) in ops {
                    match op % 8 {
                        0..=2 => {
                            // an engine publishes a completed prefix
                            let logits = logits_for(prompt);
                            s.publish(prompt, &rows_for(prompt), Some(&logits), version);
                        }
                        3..=5 => {
                            // an engine consults the store on admission
                            let min_len = (*op as usize / 8) % (prompt.len() + 1);
                            if let Some(f) = s.fetch_longest(prompt, min_len, version) {
                                if f.len <= min_len {
                                    return Err(format!(
                                        "fetch covered {} <= min_len {min_len}",
                                        f.len
                                    ));
                                }
                                if f.rows != rows_for(&prompt[..f.len]) {
                                    return Err(format!(
                                        "imported rows diverge from local compute for {:?}",
                                        &prompt[..f.len]
                                    ));
                                }
                                if let Some(l) = &f.logits {
                                    if f.len != prompt.len() || *l != logits_for(prompt) {
                                        return Err("terminal logits corrupt".into());
                                    }
                                }
                                leases.push(f.lease);
                            }
                        }
                        6 => {
                            // an importing request retires
                            if !leases.is_empty() {
                                let i = (*op as usize / 8) % leases.len();
                                s.release(leases.swap_remove(i));
                            }
                        }
                        _ => {
                            // weight sync: version bump flushes; leases stale
                            version += 1;
                            s.set_version(version);
                        }
                    }
                    s.check()?;
                    if s.live_blocks() > *capacity {
                        return Err("capacity budget violated".into());
                    }
                    // Every epoch-valid lease still pins resident segments.
                    let held: usize = leases
                        .iter()
                        .filter(|l| l.epoch == s.current_epoch())
                        .flat_map(|l| l.keys.iter())
                        .collect::<std::collections::HashSet<_>>()
                        .len();
                    if s.leased_blocks() != held {
                        return Err(format!(
                            "{} leased blocks vs {held} distinct held keys",
                            s.leased_blocks()
                        ));
                    }
                }
                for l in leases.drain(..) {
                    s.release(l);
                }
                if s.leased_blocks() != 0 {
                    return Err("refcounts leaked after full release".into());
                }
                version += 1;
                s.set_version(version);
                if s.live_blocks() != 0 {
                    return Err("store not empty after flush".into());
                }
                s.check()
            },
        );
    }
}
