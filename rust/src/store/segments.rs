//! The store core: a content-addressed map of block-granular KV segments.
//!
//! One entry covers the KV rows of one *block* of a published prefix —
//! token positions `[start, end)` where `end` is a `block_tokens` multiple
//! (or the prefix's full, unaligned length for the terminal tail) — keyed by
//! the hash of the **whole prefix through `end`** ([`super::hash`]). Chained
//! prefix keys make segments composable: a fetch walks block boundaries of
//! the query, accumulating consecutive hits, and stops at the first miss, so
//! any published prefix is importable at block granularity by any prompt
//! that shares it. Publishing is idempotent per block (same prefix ⇒ same
//! key), which is exactly the cross-engine dedup: two engines that prefilled
//! the same few-shot template store its blocks once.
//!
//! Capacity is a block budget with LRU/FIFO eviction of **unleased** entries
//! (a linear scan — the store is host-side and modest-sized; the per-engine
//! radix cache is where the O(log n) heap lives). Evicting a mid-chain block
//! orphans its deeper blocks for matching — fetches stop at the hole — but a
//! later re-publication heals the hole in place; orphans age out by policy.
//!
//! Consistency: entries are valid only for the params version that produced
//! them. [`StoreCore::set_version`] flushes on a real version bump and bumps
//! the lease epoch so releases from before the flush are ignored (the same
//! discipline as [`crate::engine::PrefixCache::clear`]).

use super::hash::PrefixHasher;
use super::stats::StoreStats;
use crate::engine::kvcache::EvictPolicy;
use std::collections::HashMap;

/// One block-granular segment: KV rows for `[end - tokens.len(), end)` of
/// some published prefix.
#[derive(Debug)]
struct Entry {
    /// Prefix length this entry completes.
    end: usize,
    /// The block's own token fragment (hash-collision guard).
    tokens: Vec<u32>,
    /// Token-major KV rows for the fragment (`tokens.len() * row_elems`).
    rows: Vec<f32>,
    /// Last-position prefill logits when a complete published prompt ends
    /// exactly at `end`.
    logits: Option<Vec<f32>>,
    /// Active cross-engine leases pinning this entry against eviction.
    refs: u32,
    last_use: u64,
    created: u64,
}

/// What a publish call did (the engine consumes its per-sync publish budget
/// only on `Stored` publishes that had to evict).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Publish {
    /// At least one new block entry was stored; `evicted` counts resident
    /// entries displaced to make room (0 = free-space growth).
    Stored { blocks: usize, evicted: usize },
    /// Every block was already resident (cross-engine dedup), or there was
    /// nothing shareable at block granularity.
    Duplicate,
    /// Nothing stored: eviction could not free capacity.
    Dropped,
    /// The caller's params version does not match the store's.
    StaleVersion,
}

/// A fetch result before the facade wraps the lease.
#[derive(Debug)]
pub(crate) struct FetchedCore {
    pub len: usize,
    pub rows: Vec<f32>,
    pub logits: Option<Vec<f32>>,
    pub keys: Vec<u64>,
}

/// The store state behind the facade's mutex.
#[derive(Debug)]
pub(crate) struct StoreCore {
    block_tokens: usize,
    capacity: usize,
    policy: EvictPolicy,
    /// f32 elements per token row; learned from the first publish and
    /// enforced afterwards (all engines share one KV geometry).
    row_elems: Option<usize>,
    entries: HashMap<u64, Entry>,
    /// Params version the resident segments were computed under.
    version: Option<u64>,
    /// Lease epoch; bumped on every flush so stale releases are ignored.
    pub(crate) epoch: u64,
    tick: u64,
    pub(crate) stats: StoreStats,
}

impl StoreCore {
    pub fn new(block_tokens: usize, capacity: usize, policy: EvictPolicy) -> StoreCore {
        assert!(block_tokens > 0 && capacity > 0, "degenerate store geometry");
        StoreCore {
            block_tokens,
            capacity,
            policy,
            row_elems: None,
            entries: HashMap::new(),
            version: None,
            epoch: 0,
            tick: 0,
            stats: StoreStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn live_blocks(&self) -> usize {
        self.entries.len()
    }

    pub fn leased_blocks(&self) -> usize {
        self.entries.values().filter(|e| e.refs > 0).count()
    }

    fn tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Block boundaries of an `len`-token prefix, ascending: every
    /// `block_tokens` multiple, then the unaligned tail end when present.
    fn boundaries(&self, len: usize) -> Vec<usize> {
        let bt = self.block_tokens;
        let mut out: Vec<usize> = (1..=len / bt).map(|j| j * bt).collect();
        if len % bt != 0 {
            out.push(len);
        }
        out
    }

    /// Fragment start for a boundary `end`.
    fn frag_start(&self, end: usize) -> usize {
        if end % self.block_tokens == 0 {
            end - self.block_tokens
        } else {
            end / self.block_tokens * self.block_tokens
        }
    }

    /// Bind the store to a params version. A real bump flushes every segment
    /// (cached KV is a function of the weights) and invalidates outstanding
    /// leases; re-announcing the current version keeps the store warm.
    /// Returns true when a flush happened.
    pub fn set_version(&mut self, v: u64) -> bool {
        if self.version == Some(v) {
            return false;
        }
        if self.version.is_some() {
            self.stats.clears += 1;
        }
        self.entries.clear();
        self.epoch += 1;
        self.version = Some(v);
        true
    }

    /// Publish a completed prefix: one entry per block boundary (existing
    /// blocks are deduped and LRU-refreshed; `logits` attach to the final
    /// boundary and never get erased by a later `None`). With `allow_evict`,
    /// unleased entries are evicted to make room (never this prefix's own
    /// chain — that would orphan the blocks just stored); without it, a full
    /// store drops the remainder instead, so dedup refreshes and free-space
    /// growth stay available to budget-exhausted engines. Stops at the
    /// first un-storable block, since deeper blocks would be unreachable
    /// through the hole anyway.
    pub fn publish(
        &mut self,
        tokens: &[u32],
        rows: &[f32],
        logits: Option<&[f32]>,
        version: u64,
        allow_evict: bool,
    ) -> Publish {
        assert!(!tokens.is_empty(), "cannot publish an empty prefix");
        assert_eq!(rows.len() % tokens.len(), 0, "ragged rows");
        if self.version != Some(version) {
            self.stats.version_rejects += 1;
            return Publish::StaleVersion;
        }
        let re = rows.len() / tokens.len();
        match self.row_elems {
            None => self.row_elems = Some(re),
            Some(r) => assert_eq!(r, re, "row geometry changed across engines"),
        }
        let mut hasher = PrefixHasher::new();
        let mut hashed = 0usize;
        let mut stored = 0usize;
        let mut evicted = 0usize;
        let mut dropped = false;
        // Keys of this prefix's chain verified or stored so far: the
        // eviction pass must never pick them, or storing a later block
        // would orphan the earlier ones (a fetch stops at the hole).
        let mut chain: Vec<u64> = Vec::new();
        for end in self.boundaries(tokens.len()) {
            while hashed < end {
                hasher.push(tokens[hashed]);
                hashed += 1;
            }
            let key = hasher.value();
            let start = self.frag_start(end);
            let is_last = end == tokens.len();
            let t = self.tick();
            if let Some(e) = self.entries.get_mut(&key) {
                if e.end == end && e.tokens == tokens[start..end] {
                    // Dedup hit: refresh recency, upgrade terminal logits.
                    e.last_use = t;
                    if is_last && e.logits.is_none() {
                        if let Some(l) = logits {
                            e.logits = Some(l.to_vec());
                        }
                    }
                    chain.push(key);
                    continue;
                }
                // 64-bit key collision with a different prefix: leave the
                // resident entry alone; deeper blocks of ours would be
                // unreachable past the mismatch, so stop here.
                dropped = true;
                break;
            }
            while self.entries.len() >= self.capacity {
                if !allow_evict || !self.evict_one(&chain) {
                    break;
                }
                evicted += 1;
            }
            if self.entries.len() >= self.capacity {
                self.stats.publish_drops += 1;
                dropped = true;
                break;
            }
            self.entries.insert(
                key,
                Entry {
                    end,
                    tokens: tokens[start..end].to_vec(),
                    rows: rows[start * re..end * re].to_vec(),
                    logits: if is_last { logits.map(<[f32]>::to_vec) } else { None },
                    refs: 0,
                    last_use: t,
                    created: t,
                },
            );
            chain.push(key);
            stored += 1;
        }
        if stored > 0 {
            self.stats.publishes += 1;
            self.stats.publish_blocks += stored as u64;
            Publish::Stored { blocks: stored, evicted }
        } else if dropped {
            Publish::Dropped
        } else {
            self.stats.publish_dups += 1;
            Publish::Duplicate
        }
    }

    /// Longest published prefix of `tokens` reconstructable from consecutive
    /// block entries. Returns `None` unless it covers strictly more than
    /// `min_len` tokens (the caller's local radix match — shorter coverage
    /// would import nothing new). On a hit, every matched entry gains a
    /// lease reference; the caller must release them via the facade.
    pub fn fetch_longest(
        &mut self,
        tokens: &[u32],
        min_len: usize,
        version: u64,
    ) -> Option<FetchedCore> {
        self.stats.fetches += 1;
        if self.version != Some(version) {
            self.stats.version_rejects += 1;
            self.stats.fetch_misses += 1;
            return None;
        }
        let Some(re) = self.row_elems else {
            // Nothing has ever been published.
            self.stats.fetch_misses += 1;
            return None;
        };
        let mut hasher = PrefixHasher::new();
        let mut hashed = 0usize;
        let mut covered = 0usize;
        let mut keys: Vec<u64> = Vec::new();
        let mut rows: Vec<f32> = Vec::new();
        let mut logits: Option<Vec<f32>> = None;
        for end in self.boundaries(tokens.len()) {
            while hashed < end {
                hasher.push(tokens[hashed]);
                hashed += 1;
            }
            let key = hasher.value();
            let Some(e) = self.entries.get(&key) else { break };
            // `covered` is exactly this entry's fragment start when the chain
            // is contiguous; verify tokens to reject hash collisions.
            if e.end != end || e.tokens != tokens[covered..end] {
                break;
            }
            rows.extend_from_slice(&e.rows);
            keys.push(key);
            covered = end;
            if covered == tokens.len() {
                logits = e.logits.clone();
            }
        }
        if covered <= min_len {
            self.stats.fetch_misses += 1;
            return None;
        }
        let t = self.tick();
        for k in &keys {
            let e = self.entries.get_mut(k).expect("matched above");
            e.refs += 1;
            e.last_use = t;
        }
        self.stats.fetch_hits += 1;
        self.stats.fetch_tokens += (covered - min_len) as u64;
        debug_assert_eq!(rows.len(), covered * re);
        Some(FetchedCore { len: covered, rows, logits, keys })
    }

    /// Drop one lease reference per key (facade guarantees epoch validity).
    pub fn release(&mut self, keys: &[u64]) {
        for k in keys {
            if let Some(e) = self.entries.get_mut(k) {
                debug_assert!(e.refs > 0, "store lease release without acquire");
                e.refs = e.refs.saturating_sub(1);
            }
        }
    }

    /// Evict the best unleased entry per the policy, never touching
    /// `protect` (the publish-in-progress chain). False when every entry is
    /// leased or protected (or the store is empty).
    fn evict_one(&mut self, protect: &[u64]) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(k, e)| e.refs == 0 && !protect.contains(*k))
            .min_by_key(|(k, e)| {
                let key = match self.policy {
                    EvictPolicy::Lru => e.last_use,
                    EvictPolicy::Fifo => e.created,
                };
                (key, **k)
            })
            .map(|(k, _)| *k);
        match victim {
            Some(k) => {
                self.entries.remove(&k);
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Structural invariants for the proptests.
    pub fn check(&self) -> Result<(), String> {
        if self.entries.len() > self.capacity {
            return Err(format!(
                "{} entries exceed capacity {}",
                self.entries.len(),
                self.capacity
            ));
        }
        for (k, e) in &self.entries {
            if e.tokens.is_empty() || e.tokens.len() > self.block_tokens {
                return Err(format!("entry {k:#x}: fragment of {} tokens", e.tokens.len()));
            }
            let start = self.frag_start(e.end);
            if e.end - start != e.tokens.len() {
                return Err(format!(
                    "entry {k:#x}: fragment {} tokens for range [{start}, {})",
                    e.tokens.len(),
                    e.end
                ));
            }
            if let Some(re) = self.row_elems {
                if e.rows.len() != e.tokens.len() * re {
                    return Err(format!("entry {k:#x}: row bookkeeping corrupt"));
                }
            }
        }
        Ok(())
    }
}
