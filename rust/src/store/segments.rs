//! Shared definitions of the store's content-addressed segment entries.
//!
//! One `Entry` covers the KV rows of one *block* of a published prefix —
//! token positions `[start, end)` where `end` is a `block_tokens` multiple
//! (or the prefix's full, unaligned length for the terminal tail) — keyed by
//! the hash of the **whole prefix through `end`** ([`super::hash`]). Chained
//! prefix keys make segments composable: a fetch walks block boundaries of
//! the query, accumulating consecutive hits, and stops at the first miss, so
//! any published prefix is importable at block granularity by any prompt
//! that shares it. Publishing is idempotent per block (same prefix ⇒ same
//! key), which is exactly the cross-engine dedup: two engines that prefilled
//! the same few-shot template store its blocks once.
//!
//! The map itself — capacity, eviction, leases, versioning — lives in
//! [`super::shard`]: the store is a set of independent `Shard`s, each owning
//! one hash range of chains. These types are what the shards and the
//! [`super::SharedKvStore`] facade exchange.

/// One block-granular segment: KV rows for `[end - tokens.len(), end)` of
/// some published prefix.
#[derive(Debug)]
pub(crate) struct Entry {
    /// Prefix length this entry completes.
    pub(crate) end: usize,
    /// The block's own token fragment (hash-collision guard).
    pub(crate) tokens: Vec<u32>,
    /// Token-major KV rows for the fragment (`tokens.len() * row_elems`).
    pub(crate) rows: Vec<f32>,
    /// Last-position prefill logits when a complete published prompt ends
    /// exactly at `end`.
    pub(crate) logits: Option<Vec<f32>>,
    /// Active cross-engine leases pinning this entry against eviction.
    pub(crate) refs: u32,
    pub(crate) last_use: u64,
    pub(crate) created: u64,
}

/// What a publish call did (the engine consumes its per-sync publish budget
/// only on `Stored` publishes that had to evict).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Publish {
    /// At least one new block entry was stored; `evicted` counts resident
    /// entries displaced to make room (0 = free-space growth).
    Stored { blocks: usize, evicted: usize },
    /// Every block was already resident (cross-engine dedup), or there was
    /// nothing shareable at block granularity.
    Duplicate,
    /// Nothing stored: eviction could not free capacity.
    Dropped,
    /// The caller's params version does not match the store's.
    StaleVersion,
}

/// A fetch result before the facade wraps the lease.
#[derive(Debug)]
pub(crate) struct FetchedCore {
    pub len: usize,
    pub rows: Vec<f32>,
    pub logits: Option<Vec<f32>>,
    pub keys: Vec<u64>,
}
