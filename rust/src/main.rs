//! `pa-rl` — command-line launcher.
//!
//! ```text
//! pa-rl train     --config configs/small.json --mode async [--spa] [--iters N]
//! pa-rl simulate  --table 1..5|prefix|all [--iters N]
//! pa-rl inspect   --config configs/small.json
//! pa-rl eval      --config configs/small.json --n 64 [--seed S]
//! ```
//!
//! The examples/ binaries cover richer flows (SFT warmup, CSV curves,
//! equivalence checking, serving benchmarks); this launcher is the minimal
//! production entrypoint.

use anyhow::{bail, Result};
use pa_rl::config::Config;
use pa_rl::coordinator::{evaluate, Driver, DriverOpts, Mode};
use pa_rl::runtime::{Manifest, Runtime};
use pa_rl::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: pa-rl <train|simulate|inspect|eval> [--options]
  train     --config FILE [--mode sync|async|stale] [--spa] [--iters N] [--seed S]
  simulate  [--table 1|2|3|4|5|prefix|all] [--iters N]
  inspect   --config FILE
  eval      --config FILE [--n N] [--seed S]";

fn main() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("eval") => cmd_eval(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn load_cfg(args: &Args) -> Result<(Config, PathBuf)> {
    let config_path = args.str_or("config", "configs/tiny.json");
    let cfg = Config::load(Path::new(&config_path))?;
    let artifacts = PathBuf::from(cfg.artifacts_dir());
    if !artifacts.join("manifest.json").exists() {
        bail!(
            "artifacts missing at {} — run `make artifacts CONFIG={}`",
            artifacts.display(),
            config_path
        );
    }
    Ok((cfg, artifacts))
}

fn cmd_train(args: &Args) -> Result<()> {
    let (cfg, artifacts) = load_cfg(args)?;
    let mode = Mode::parse(&args.str_or("mode", "async"))?;
    let opts = DriverOpts { mode, spa: args.has_flag("spa"), seed: args.u64_or("seed", 0) };
    let iters = args.u64_or("iters", cfg.rl.iters as u64);
    let mut driver = Driver::new(cfg.clone(), &artifacts, opts)?;
    for t in 0..iters {
        let rep = driver.run(1)?;
        let it = &rep.iters[0];
        println!(
            "iter {t:>3}  reward {:>6.3}  loss {:>9.5}  kl {:>8.5}  wall {:>6.2}s  tokens {:>7}  kv-hit {:>4.0}%  prefills {:>4}(-{})  chunks {:>4}  saved {:>6}  xeng {:>3}(+{})  spill {:>3}  eng {:>2}",
            it.reward_mean,
            it.stats.loss,
            it.stats.kl,
            it.wall_seconds,
            it.train_input_tokens,
            it.kv_hit_rate * 100.0,
            it.prefills,
            it.prefills_skipped,
            it.prefill_chunks,
            it.prefill_tokens_saved,
            it.cross_engine_hits,
            it.cross_engine_tokens,
            it.affinity_spills,
            it.engines
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    // Compact table printer; see examples/simulate_cluster.rs for the full
    // side-by-side comparison output.
    use pa_rl::sim::experiments;
    use pa_rl::util::bench::{f3, Table};
    let iters = args.usize_or("iters", 3);
    let which = args.str_or("table", "all");
    let print = |title: &str, rows: &[experiments::Row]| {
        let mut t = Table::new(title, &["Setting", "Paper TPSPD", "Sim TPSPD"]);
        for r in rows {
            t.row(&[
                r.setting.clone(),
                r.paper_tpspd.map(f3).unwrap_or_default(),
                f3(r.sim.tpspd),
            ]);
        }
        t.print();
    };
    if which == "1" || which == "all" {
        print("Table 1", &experiments::table1(iters));
    }
    if which == "2" || which == "all" {
        let (g1, g2) = experiments::table2(iters);
        print("Table 2 (group 1)", &g1);
        print("Table 2 (group 2)", &g2);
    }
    if which == "3" || which == "all" {
        print("Table 3", &experiments::table3(iters));
    }
    if which == "4" || which == "all" {
        print("Table 4", &experiments::table4(iters));
    }
    if which == "5" || which == "all" {
        let mut t = Table::new("Table 5 / Fig 6", &["NPUs", "Paper TPSPD", "Sim TPSPD"]);
        for (n, paper, sim) in experiments::table5(iters) {
            t.row(&[format!("{n}"), paper.map(f3).unwrap_or_default(), f3(sim.tpspd)]);
        }
        t.print();
    }
    if which == "prefix" || which == "all" {
        print("Prefix-cache ablation", &experiments::prefix_cache_ablation(iters));
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let (cfg, artifacts) = load_cfg(args)?;
    let manifest = Manifest::load(&artifacts)?;
    println!("config:      {}", cfg.name);
    println!(
        "params:      {} ({:.2} MB f32)",
        manifest.param_count,
        manifest.param_count as f64 * 4e-6
    );
    println!("attn impl:   {}", manifest.attn_impl);
    println!("fingerprint: {}", manifest.fingerprint);
    println!("kv cache:    {:?}", manifest.kv_cache.shape);
    println!("artifacts:");
    for (name, a) in &manifest.artifacts {
        let size = std::fs::metadata(&a.file).map(|m| m.len()).unwrap_or(0);
        println!(
            "  {name:<16} {:>4} inputs  {:>3} outputs  {:>8} bytes",
            a.inputs.len(),
            a.outputs.len(),
            size
        );
    }
    println!("param table:");
    for p in &manifest.params {
        println!("  {:<10} {:?}", p.name, p.shape);
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (cfg, artifacts) = load_cfg(args)?;
    let n = args.usize_or("n", 64);
    let rt = Runtime::load_validated(&artifacts, &cfg)?;
    let params = rt.init_params(args.u64_or("seed", 0) as i32)?;
    drop(rt);
    let report = evaluate(&cfg, &artifacts, &params, n)?;
    println!(
        "accuracy {:.3} ({}/{}), mean response length {:.1}",
        report.accuracy, report.correct, report.n, report.mean_response_len
    );
    Ok(())
}
