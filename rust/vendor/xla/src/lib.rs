//! Stub of the `xla` (xla-rs) API surface pa-rl uses.
//!
//! The build environment has no network access and no prebuilt
//! `xla_extension`, so this crate provides the exact types and signatures
//! `pa_rl::runtime` compiles against:
//!
//! * **fully functional on the host**: [`Literal`] (typed storage + shape,
//!   reshape, readback) and [`Shape`]/[`ArrayShape`]/[`ElementType`] — the
//!   tensor round-trip tests in `pa_rl::runtime::tensor` exercise these;
//! * **stubbed**: [`PjRtClient::cpu`] returns an error explaining that no
//!   PJRT backend is linked, so every execution path fails fast with a clear
//!   message instead of segfaulting or silently fabricating results.
//!
//! To run compiled artifacts for real, replace this directory with the
//! actual xla-rs bindings (same module paths) and build with
//! `--features pjrt`; until then that feature is a compile-time error so a
//! half-configured build cannot look runnable.

#[cfg(feature = "pjrt")]
compile_error!(
    "the vendored `xla` stub has no PJRT backend: replace rust/vendor/xla \
     with the real xla-rs bindings (github.com/LaurentMazare/xla-rs, plus an \
     xla_extension install) before enabling the `pjrt` feature"
);

use std::fmt;

/// Error type mirroring xla-rs's (which also implements `std::error::Error`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_backend<T>(what: &str) -> Result<T> {
    Err(Error::msg(format!(
        "{what} requires a PJRT backend, but pa-rl was built against the \
         vendored xla stub (rust/vendor/xla). Vendor the real xla-rs bindings \
         and build with --features pjrt to execute compiled artifacts"
    )))
}

/// Element types pa-rl encounters (subset of xla-rs's `ElementType`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

/// Typed host storage behind a [`Literal`].
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum LitData {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

impl LitData {
    fn len(&self) -> usize {
        match self {
            LitData::F32(v) => v.len(),
            LitData::S32(v) => v.len(),
        }
    }

    fn element_type(&self) -> ElementType {
        match self {
            LitData::F32(_) => ElementType::F32,
            LitData::S32(_) => ElementType::S32,
        }
    }
}

/// Element types storable in a stub [`Literal`].
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> LitData;
    #[doc(hidden)]
    fn unwrap(data: &LitData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> LitData {
        LitData::F32(data)
    }
    fn unwrap(data: &LitData) -> Option<Vec<Self>> {
        match data {
            LitData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> LitData {
        LitData::S32(data)
    }
    fn unwrap(data: &LitData) -> Option<Vec<Self>> {
        match data {
            LitData::S32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side typed array with a shape (fully functional in the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: LitData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error::msg(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape { element_type: self.data.element_type(), dims: self.dims.clone() })
    }

    /// Read the elements back out as a typed vec.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error::msg(format!("literal holds {:?}", self.data.element_type()))
        })
    }

    /// Flatten a tuple literal. The stub never constructs tuples (they only
    /// arise from PJRT execution results), so this is always an error here.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::msg("stub literal is not a tuple"))
    }
}

/// An array-or-tuple shape, as returned by [`Literal::shape`].
#[derive(Debug, Clone, PartialEq)]
pub struct Shape {
    element_type: ElementType,
    dims: Vec<i64>,
}

/// The array view of a [`Shape`].
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    element_type: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.element_type
    }
}

impl TryFrom<&Shape> for ArrayShape {
    type Error = Error;

    fn try_from(s: &Shape) -> Result<ArrayShape> {
        Ok(ArrayShape { element_type: s.element_type, dims: s.dims.clone() })
    }
}

/// A parsed HLO module (opaque in the stub).
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        no_backend("parsing HLO text")
    }
}

/// A computation ready to compile (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device buffer (host-backed in the stub).
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_backend("executing a compiled artifact")
    }
}

/// A PJRT client (never constructible in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        no_backend("creating a PJRT CPU client")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_backend("compiling an XLA computation")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { literal: Literal::vec1(data).reshape(&dims)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        let shape = r.shape().unwrap();
        let arr = ArrayShape::try_from(&shape).unwrap();
        assert_eq!(arr.dims(), &[2, 2]);
        assert_eq!(arr.element_type(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(lit.shape().unwrap().dims, Vec::<i64>::new());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn pjrt_paths_fail_loudly() {
        let err = PjRtClient::cpu().err().expect("stub has no backend");
        assert!(err.to_string().contains("PJRT"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
