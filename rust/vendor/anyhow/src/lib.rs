//! Minimal, offline-vendored substitute for the `anyhow` crate.
//!
//! The build environment has no registry access, so pa-rl vendors the small
//! part of anyhow's API it actually uses:
//!
//! * [`Error`] — an erased error with a context chain (like anyhow, it does
//!   **not** implement `std::error::Error`, which is what allows the blanket
//!   `From<E: std::error::Error>` conversion `?` relies on);
//! * [`Result<T>`] — `std::result::Result` with `Error` as the default error
//!   type (the second generic parameter is kept so `Result<T, OtherError>`
//!   still resolves in modules that import this alias);
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`].
//!
//! Error sources are flattened into a message chain at conversion time (the
//! real anyhow keeps the boxed source alive for downcasting; nothing in pa-rl
//! downcasts, so strings are enough and keep this dependency-free).

use std::convert::Infallible;
use std::fmt;

/// `std::result::Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: an outermost message plus the chain of underlying causes
/// (outermost first). Deliberately does not implement `std::error::Error` so
/// the blanket `From` below cannot overlap the reflexive `From<Error>`.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain inline, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` itself does not implement `std::error::Error`, so this blanket
// cannot overlap core's reflexive `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to failures: implemented for `Result` over any std error,
/// `Result` over [`Error`] itself, and `Option`.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Disjoint from the impl above because `Error: !std::error::Error` is known
// locally (same coherence carve-out the real anyhow relies on).
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_wraps_result_and_option() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by"));

        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn context_chains_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("inner {} failed", 7);
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause(), "inner 7 failed");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn guarded(v: usize) -> Result<usize> {
            ensure!(v < 10, "too big: {v}");
            Ok(v)
        }
        assert!(guarded(3).is_ok());
        assert!(guarded(30).unwrap_err().to_string().contains("too big"));
    }
}
