//! Quickstart: periodic-async GRPO on the tiny config.
//!
//! ```bash
//! make artifacts CONFIG=configs/tiny.json
//! cargo run --release --example quickstart
//! ```
//!
//! Runs a few iterations of Algorithm 1 in both synchronous and periodically
//! asynchronous modes on the same synthetic arithmetic workload and prints
//! the side-by-side throughput — the paper's headline comparison, in thirty
//! seconds on a laptop.

use pa_rl::config::Config;
use pa_rl::coordinator::{Driver, DriverOpts, Mode};
use pa_rl::util::bench::{f3, fx, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let config_path = std::env::args().nth(1).unwrap_or_else(|| "configs/tiny.json".into());
    let cfg = Config::load(Path::new(&config_path))?;
    let artifacts = cfg.artifacts_dir();
    if !Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("artifacts missing — run: make artifacts CONFIG={config_path}");
        std::process::exit(1);
    }
    let iters = 3u64;

    let mut table = Table::new(
        "Quickstart: periodic asynchrony vs synchronous baseline",
        &["Mode", "TPSPD (tokens/s/instance)", "Mean reward", "Consumer wait (s)", "Speedup"],
    );
    let mut sync_tpspd = None;
    for mode in [Mode::Sync, Mode::Async] {
        let opts = DriverOpts { mode, spa: false, seed: 42 };
        let mut driver = Driver::new(cfg.clone(), Path::new(&artifacts), opts)?;
        let report = driver.run(iters)?;
        let tpspd = report.tpspd();
        let wait: f64 = report.iters.iter().map(|i| i.consumer_wait_seconds).sum();
        let speedup = match sync_tpspd {
            None => {
                sync_tpspd = Some(tpspd);
                "1.00x (baseline)".to_string()
            }
            Some(s) => fx(tpspd / s),
        };
        table.row(&[
            format!("{mode:?}"),
            f3(tpspd),
            format!("{:.3}", report.mean_reward_last(iters as usize)),
            format!("{wait:.2}"),
            speedup,
        ]);
    }
    table.note("same seed, same engines, same trainer — only the schedule differs");
    table.print();
    Ok(())
}
