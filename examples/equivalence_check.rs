//! Machine-check of the paper's correctness claims (Prop. 1 + Remark 1):
//! the asynchronous schedule produces the *same parameter update* as the
//! synchronous one.
//!
//! Two checks:
//! 1. **Remark 1 (permutation invariance)** — one generated batch fed to two
//!    trainers in different consumption orders must yield identical updated
//!    parameters (up to float-summation reordering, ~1e-6).
//! 2. **End-to-end** — full sync and async driver runs with identical seeds:
//!    rollouts are identical (weights sync at the same boundaries, engine RNG
//!    streams match), so the final policies must agree to the same tolerance,
//!    and every consumed rollout carries the current policy version.
//!
//! ```bash
//! cargo run --release --example equivalence_check -- --config configs/tiny.json
//! ```

use pa_rl::config::Config;
use pa_rl::coordinator::{Driver, DriverOpts, Mode};
use pa_rl::data::DataLoader;
use pa_rl::engine::{Engine, GenRequest};
use pa_rl::grpo::{group_advantages, Group, Rollout};
use pa_rl::runtime::Runtime;
use pa_rl::train::{IterStats, Trainer};
use pa_rl::util::cli::Args;
use pa_rl::util::rng::Pcg64;
use std::path::{Path, PathBuf};

fn max_param_diff(a: &pa_rl::runtime::HostParams, b: &pa_rl::runtime::HostParams) -> f32 {
    let mut worst = 0.0f32;
    for (x, y) in a.tensors.iter().zip(&b.tensors) {
        for (u, v) in x.as_f32().unwrap().iter().zip(y.as_f32().unwrap()) {
            worst = worst.max((u - v).abs());
        }
    }
    worst
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let config_path = args.str_or("config", "configs/tiny.json");
    let cfg = Config::load(Path::new(&config_path))?;
    let artifacts = PathBuf::from(cfg.artifacts_dir());

    // ---- check 1: Remark 1 at trainer level -----------------------------
    println!("[1/2] Remark 1: gradient permutation invariance");
    let rt = Runtime::load_validated(&artifacts, &cfg)?;
    let params = rt.init_params(123)?;
    let mut engine = Engine::new(cfg.clone(), rt, 7);
    engine.set_weights(&params)?;
    let mut loader = DataLoader::new(cfg.data.clone());
    let prompts = loader.next_batch(cfg.rl.batch_prompts);
    let g = cfg.rl.group_size;
    let mut reqs = Vec::new();
    for (pi, p) in prompts.iter().enumerate() {
        for s in 0..g {
            reqs.push(GenRequest { request_id: (pi * g + s) as u64, prompt: p.tokens.clone(), ..Default::default() });
        }
    }
    let results = engine.generate_all(reqs)?;
    let tokenizer = pa_rl::data::Tokenizer::new();
    let mut groups = Vec::new();
    for (pi, p) in prompts.iter().enumerate() {
        let mut rollouts: Vec<Rollout> = results
            .iter()
            .filter(|r| (r.request_id as usize) / g == pi)
            .map(|r| Rollout {
                sample_idx: (r.request_id as usize) % g,
                weight_version: r.weight_version,
                tokens: r.tokens.clone(),
                logprobs: r.logprobs.clone(),
                reward: pa_rl::grpo::reward::score(&tokenizer, &r.tokens, p.answer),
                timeline: r.timeline,
            })
            .collect();
        rollouts.sort_by_key(|r| r.sample_idx);
        let rewards: Vec<f32> = rollouts.iter().map(|r| r.reward).collect();
        groups.push(Group {
            prompt: p.clone(),
            weight_version: 0,
            advantages: group_advantages(&rewards),
            rollouts,
            gen_seconds: 0.0,
        });
    }

    let train_in_order = |order: &[usize]| -> anyhow::Result<pa_rl::runtime::HostParams> {
        let rt = Runtime::load_validated(&artifacts, &cfg)?;
        let mut trainer = Trainer::with_params(cfg.clone(), rt, params.clone())?;
        let mut stats = IterStats::default();
        trainer.begin_iteration()?;
        for &i in order {
            trainer.train_group(&groups[i], false, &mut stats)?;
        }
        trainer.end_iteration(&mut stats)?;
        Ok(trainer.policy().clone())
    };
    let forward: Vec<usize> = (0..groups.len()).collect();
    let mut shuffled = forward.clone();
    Pcg64::seeded(99).shuffle(&mut shuffled);
    println!("  consumption orders: {forward:?} vs {shuffled:?}");
    let p1 = train_in_order(&forward)?;
    let p2 = train_in_order(&shuffled)?;
    let diff = max_param_diff(&p1, &p2);
    println!("  max |param diff| = {diff:.2e}  (tolerance 1e-5)");
    assert!(diff < 1e-5, "Remark 1 violated: {diff}");
    println!("  PASS: accumulated update is permutation-invariant\n");

    // ---- check 2: full sync vs async runs --------------------------------
    println!("[2/2] Proposition 1: sync and async drivers converge identically");
    let run = |mode: Mode| -> anyhow::Result<pa_rl::runtime::HostParams> {
        let opts = DriverOpts { mode, spa: false, seed: 2024 };
        let mut driver = Driver::new(cfg.clone(), &artifacts, opts)?;
        driver.run(2)?;
        Ok(driver.trainer().policy().clone())
    };
    let sync_params = run(Mode::Sync)?;
    let async_params = run(Mode::Async)?;
    let diff = max_param_diff(&sync_params, &async_params);
    // Gradients agree to float-summation reordering (~1e-7), but Adam
    // normalises by sqrt(v): with near-zero second moments the *sign* of a
    // ~1e-7 gradient decides a ~lr-sized step, so the principled bound on
    // parameter divergence is a few lr per iteration — not 1e-7.
    let tol = 4.0 * cfg.train.lr as f32 * 2.0;
    println!("  max |param diff| after 2 iterations = {diff:.2e}  (adam-noise tolerance {tol:.1e})");
    assert!(
        diff < tol,
        "sync/async diverged by {diff} — periodic asynchrony should be gradient-equivalent"
    );
    println!("  PASS: periodic asynchrony is on-policy and update-equivalent");
    Ok(())
}
