//! Standalone inference-engine benchmark: continuous batching under a prompt
//! stream, reporting serving-style latency/throughput (the vLLM-substrate
//! half of the system in isolation).
//!
//! `--group G` replays each prompt G times (GRPO-style grouped traffic, or a
//! serving workload with repeated prompts): with the shared-prefix KV cache
//! enabled, only the first occurrence runs the compiled prefill and the
//! report shows the cache hit rate and skipped prefills.
//!
//! `--engines E` runs E engine instances behind residency-aware routing with
//! the cross-engine shared segment store attached (the coordinator's serving
//! topology, minus the trainer): groups prefer the engine whose cache
//! verifiably holds their template warm, spills import it from the store,
//! and the report shows `cross-engine hits` — prompts admitted without
//! recomputing a prefix some other engine already paid for.
//!
//! `--store-shards S` overrides the store's shard count (default: the
//! config's `engine.store_shards`) — S independent locks over the hash
//! ranges instead of one global mutex.
//!
//! `--leave N` drops the last N engines *mid-run* (after half the groups
//! have been served): the router's warmth map forgets them and the second
//! half of the traffic redistributes over the survivors, importing
//! store-covered templates instead of recomputing them — the fleet-resize
//! story end-to-end. `--join N` is the mirror image: N cold engines join at
//! the same midpoint, weight-synced and store-attached before they see
//! traffic, exactly like the coordinator's `Driver::spawn_engine`. Joins
//! apply before leaves, so `--join 1 --leave 1` is a rolling replacement.
//!
//! With `rl.warmth_ttl` set in the config, the router's warmth beliefs
//! decay: every dispatched group advances the decay clock one epoch, and a
//! template not re-dispatched (there are no stats refreshes in this loop)
//! within its TTL window falls back to the hash spread — how a long-running
//! server forgets departed or rarely-used templates.
//!
//! ```bash
//! cargo run --release --example serve_infer -- --config configs/tiny.json --requests 64
//! cargo run --release --example serve_infer -- --config configs/tiny.json --requests 64 --group 8
//! cargo run --release --example serve_infer -- --config configs/tiny.json --requests 64 --group 4 --engines 3 --store-shards 4 --leave 1
//! cargo run --release --example serve_infer -- --config configs/tiny.json --requests 64 --group 4 --engines 2 --join 2
//! ```

use pa_rl::config::Config;
use pa_rl::coordinator::route;
use pa_rl::data::DataLoader;
use pa_rl::engine::{Engine, GenRequest, GenResult};
use pa_rl::metrics::{Clock, MetricsLevel, RequestMetrics};
use pa_rl::runtime::Runtime;
use pa_rl::store::{SharedKvStore, StoreCfg};
use pa_rl::util::bench::Table;
use pa_rl::util::cli::Args;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let config_path = args.str_or("config", "configs/tiny.json");
    let n_requests = args.usize_or("requests", 64);
    let group = args.usize_or("group", 1).max(1);
    let n_engines = args.usize_or("engines", 1).max(1);
    let store_shards = args.usize_or("store-shards", 0); // 0 = config default
    let join = args.usize_or("join", 0);
    let leave = args.usize_or("leave", 0).min((n_engines + join).saturating_sub(1));
    let seed = args.u64_or("seed", 0);

    let cfg = Config::load(Path::new(&config_path))?;
    // --metrics basic|full (default: the config's `metrics.level`). Full
    // stamps per-request lifecycle timelines and adds TTFT / queue-wait
    // percentile rows to the report; basic output is unchanged.
    let metrics_level = match args.get("metrics") {
        Some(l) => MetricsLevel::parse(&l)
            .ok_or_else(|| anyhow::anyhow!("--metrics expects basic|full, got '{l}'"))?,
        None => cfg.metrics.level,
    };
    let clock = metrics_level.is_full().then(Clock::new);
    let artifacts = cfg.artifacts_dir();
    let mut eager = vec!["init", "prefill", "decode"];
    if cfg.engine.prefix_cache && cfg.engine.chunked_prefill {
        // Compile ahead of the timed region so the first partial-prefix
        // admission doesn't absorb a JIT compile into the latency numbers.
        // (prepare() skips it gracefully if the manifest predates chunking.)
        let probe = Runtime::load_validated(Path::new(&artifacts), &cfg)?;
        if probe.manifest().artifacts.contains_key("prefill_chunk") {
            eager.push("prefill_chunk");
        }
    }
    let mut params = None;
    // One engine instance, weight-synced — shared by the startup fleet and
    // by mid-run joiners (same seed convention as the coordinator).
    let mk_engine = |idx: usize,
                     params: &mut Option<pa_rl::runtime::HostParams>|
     -> anyhow::Result<Engine> {
        let rt = Runtime::load_validated(Path::new(&artifacts), &cfg)?;
        rt.prepare(&eager)?;
        if params.is_none() {
            *params = Some(rt.init_params(seed as i32)?);
        }
        let mut engine = Engine::new(cfg.clone(), rt, seed ^ (idx as u64).wrapping_mul(0x9E37));
        if let Some(c) = &clock {
            engine.set_telemetry(*c);
        }
        engine.set_weights(params.as_ref().unwrap())?;
        Ok(engine)
    };
    let mut engines: Vec<Engine> = Vec::with_capacity(n_engines + join);
    for idx in 0..n_engines {
        engines.push(mk_engine(idx, &mut params)?);
    }

    // Cross-engine store: the coordinator's serving topology, sized for the
    // peak fleet (`--join` engines import from it the moment they arrive).
    // Shard count from the config unless overridden, clamped so every
    // shard's capacity slice still holds one full prompt's chain (chains
    // are shard-affine).
    let max_shards = (cfg.engine.store_blocks / cfg.engine.blocks_per_prompt().max(1)).max(1);
    let shards =
        if store_shards == 0 { cfg.engine.store_shards } else { store_shards }.clamp(1, max_shards);
    let store = cfg.store_active(n_engines + join).then(|| {
        Arc::new(SharedKvStore::new(StoreCfg {
            block_tokens: cfg.engine.cache_block,
            capacity_blocks: cfg.engine.store_blocks,
            policy: cfg.engine.store_evict,
            shards,
        }))
    });
    if let Some(s) = &store {
        for e in &mut engines {
            e.set_shared_store(s.clone());
        }
    }

    let mut loader = DataLoader::new(cfg.data.clone());
    let n_unique = n_requests.div_ceil(group);
    let prompts = loader.next_batch(n_unique);
    let affinity = cfg.affinity_active(n_engines + join);
    let slack = cfg.rl.affinity_slack_groups * group;
    // Belief decay per the config: one dispatched group = one decay epoch.
    let mut warmth = route::WarmthMap::with_ttl(cfg.rl.warmth_ttl);
    let mut spills = 0u64;
    let mut routed = 0usize;

    // Drive every live engine to completion, interleaved (so later groups on
    // one engine can import prefixes another engine published).
    let drive = |engines: &mut [Engine], results: &mut Vec<GenResult>| -> anyhow::Result<()> {
        loop {
            let mut any = false;
            for e in engines.iter_mut() {
                if !e.idle() {
                    results.extend(e.step()?);
                    any = true;
                }
            }
            if !any {
                return Ok(());
            }
        }
    };

    // Grouped traffic, group-affine: a prompt's repeats all land on one
    // engine (like the coordinator), chosen by residency-aware routing —
    // gated exactly like the driver, else the round-robin group pin.
    let dispatch = |engines: &mut Vec<Engine>,
                        warmth: &mut route::WarmthMap,
                        spills: &mut u64,
                        lo: usize,
                        hi: usize| {
        let mut load = vec![0usize; engines.len()];
        for i in lo..hi {
            let (idx, spilled) = if affinity {
                let resident = store
                    .as_ref()
                    .map_or(0, |s| s.residency_blocks(&prompts[i].tokens));
                let (idx, kind) = route::route_group_residency(
                    &prompts[i].tokens,
                    cfg.engine.cache_block,
                    &load,
                    slack,
                    warmth,
                    resident,
                );
                let (key, alen) = route::affinity_key(&prompts[i].tokens, cfg.engine.cache_block);
                warmth.note(key, idx, alen);
                (idx, kind.is_spill())
            } else {
                (i % engines.len(), false)
            };
            if spilled {
                *spills += 1;
            }
            // One dispatched group = one decay epoch for the warmth beliefs
            // (no-op at the default `rl.warmth_ttl` of 0).
            warmth.advance();
            let repeats = group.min(n_requests - i * group);
            for s in 0..repeats {
                let mut req = GenRequest {
                    request_id: (i * group + s) as u64,
                    prompt: prompts[i].tokens.clone(),
                    ..Default::default()
                };
                if let Some(c) = &clock {
                    // Submission is both enqueue and dispatch here — there
                    // is no coordinator queue between client and engine.
                    let now = c.now();
                    req.timeline.enqueue_s = now;
                    req.timeline.dispatch_s = now;
                }
                engines[idx].submit(req);
            }
            load[idx] += repeats;
        }
    };

    let t0 = std::time::Instant::now();
    let mut results: Vec<GenResult> = Vec::with_capacity(n_requests);
    let resize = join > 0 || leave > 0;
    let split = if resize { n_unique / 2 } else { n_unique };

    // Phase 1: the starting fleet serves the first half of the groups.
    dispatch(&mut engines, &mut warmth, &mut spills, 0, split);
    routed += split;
    drive(&mut engines, &mut results)?;

    // Mid-run fleet resize. Joins first (a `--join N --leave N` run is a
    // rolling replacement): new engines arrive weight-synced and
    // store-attached, cold but able to import every template the store
    // holds. Then the last `leave` engines depart: their warmth beliefs are
    // dropped and their templates re-route over the survivors by hash,
    // re-importing from the shared store instead of recomputing.
    let mut joined = 0usize;
    let mut departed = 0usize;
    if resize && split < n_unique {
        for j in 0..join {
            let mut e = mk_engine(n_engines + j, &mut params)?;
            if let Some(s) = &store {
                e.set_shared_store(s.clone());
            }
            engines.push(e);
        }
        joined = join;
        for _ in 0..leave {
            let idx = engines.len() - 1;
            let _gone = engines.pop().expect("leave < peak fleet");
            warmth.remove_engine(idx, engines.len());
        }
        departed = leave;
        dispatch(&mut engines, &mut warmth, &mut spills, split, n_unique);
        routed += n_unique - split;
        drive(&mut engines, &mut results)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = results.iter().map(|r| r.seconds).collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Graceful on an empty run (`--requests 0`): report 0 rather than
    // indexing an empty vector.
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[((latencies.len() as f64 - 1.0) * p).round() as usize]
        }
    };
    let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let finished = results
        .iter()
        .filter(|r| r.tokens.last() == Some(&pa_rl::data::EOS))
        .count();
    let sum = |f: fn(&pa_rl::engine::EngineStats) -> u64| -> u64 {
        engines.iter().map(|e| f(&e.stats)).sum()
    };

    let mut t = Table::new(
        "Inference engine: continuous batching benchmark",
        &["Metric", "Value"],
    );
    t.row(&["requests".into(), format!("{n_requests}")]);
    t.row(&["group size".into(), format!("{group}")]);
    t.row(&["engines".into(), format!("{n_engines}")]);
    if joined > 0 {
        t.row(&["engines joined mid-run".into(), format!("{joined}")]);
    }
    if departed > 0 {
        t.row(&["engines departed mid-run".into(), format!("{departed}")]);
    }
    t.row(&["slots / engine".into(), format!("{}", cfg.engine.n_slots)]);
    t.row(&["decode chunk".into(), format!("{}", cfg.engine.decode_chunk)]);
    t.row(&["wall (s)".into(), format!("{wall:.3}")]);
    t.row(&["generated tokens".into(), format!("{total_tokens}")]);
    t.row(&["tokens / s".into(), format!("{:.1}", total_tokens as f64 / wall)]);
    t.row(&["requests / s".into(), format!("{:.2}", n_requests as f64 / wall)]);
    t.row(&["latency p50 (s)".into(), format!("{:.3}", pct(0.5))]);
    t.row(&["latency p95 (s)".into(), format!("{:.3}", pct(0.95))]);
    t.row(&["latency max (s)".into(), format!("{:.3}", pct(1.0))]);
    if clock.is_some() {
        // Full telemetry: fold the stamped timelines into the standard
        // request-metrics histograms (same aggregation as the coordinator).
        // A histogram can still be empty (e.g. `--requests 0`, or timelines
        // the engine never stamped) — say so instead of printing quantiles
        // of nothing.
        let mut rm = RequestMetrics::default();
        for r in &results {
            rm.observe(&r.timeline, 0);
        }
        let q2 = |h: &pa_rl::metrics::Histogram| -> String {
            if h.is_empty() {
                "n/a (no stamped timelines)".into()
            } else {
                format!("{:.3}/{:.3}", h.quantile(0.50), h.quantile(0.99))
            }
        };
        t.row(&["ttft p50/p99 (s)".into(), q2(&rm.ttft)]);
        t.row(&["queue wait p50/p99 (s)".into(), q2(&rm.queue_wait)]);
        t.row(&[
            "decode tok/s p50".into(),
            if rm.decode_tps.is_empty() {
                "n/a (no stamped timelines)".into()
            } else {
                format!("{:.0}", rm.decode_tps.quantile(0.50))
            },
        ]);
    } else {
        // Basic level: the lifecycle quantile rows need per-request
        // timestamps we deliberately don't take. Degrade explicitly rather
        // than omitting the rows without a word.
        t.row(&[
            "ttft / queue wait".into(),
            "off at metrics.level=basic (rerun with --metrics full)".into(),
        ]);
    }
    t.row(&["EOS-terminated".into(), format!("{finished}/{n_requests}")]);
    t.row(&["prefills (compiled)".into(), format!("{}", sum(|s| s.prefills))]);
    t.row(&["prefills skipped".into(), format!("{}", sum(|s| s.prefills_skipped))]);
    t.row(&["prefill chunks".into(), format!("{}", sum(|s| s.prefill_chunks))]);
    t.row(&[
        "prefill tokens saved".into(),
        format!("{}", sum(|s| s.prefill_tokens_saved)),
    ]);
    t.row(&["decode chunks".into(), format!("{}", sum(|s| s.decode_chunks))]);
    let mut cache_on = false;
    let (mut hit, mut miss, mut partial, mut bytes, mut evictions) = (0, 0, 0, 0, 0);
    for e in &engines {
        if let Some(c) = e.cache_stats() {
            cache_on = true;
            hit += c.hit_tokens;
            miss += c.miss_tokens;
            partial += c.partial_hits;
            bytes += c.bytes_saved;
            evictions += c.evictions;
        }
    }
    if cache_on {
        let rate = if hit + miss == 0 { 0.0 } else { hit as f64 / (hit + miss) as f64 };
        t.row(&["prefix cache".into(), "on".into()]);
        t.row(&["kv hit rate".into(), format!("{:.1}%", rate * 100.0)]);
        t.row(&["prompt tokens hit/miss".into(), format!("{hit}/{miss}")]);
        t.row(&["partial-prefix hits".into(), format!("{partial}")]);
        t.row(&["kv bytes saved".into(), format!("{bytes}")]);
        t.row(&["cache evictions".into(), format!("{evictions}")]);
    } else {
        t.row(&["prefix cache".into(), "off".into()]);
    }
    match &store {
        Some(s) => {
            let ss = s.stats();
            t.row(&["shared store".into(), "on".into()]);
            t.row(&["store shards".into(), format!("{}", s.shard_count())]);
            t.row(&["cross-engine hits".into(), format!("{}", sum(|st| st.cross_engine_hits))]);
            t.row(&[
                "cross-engine tokens".into(),
                format!("{}", sum(|st| st.cross_engine_tokens)),
            ]);
            t.row(&["store publishes".into(), format!("{}", ss.publishes)]);
            t.row(&["store evictions (heap probes)".into(), format!("{} ({})", ss.evictions, ss.evict_probes)]);
            t.row(&[
                "store blocks live/cap".into(),
                format!("{}/{}", s.live_blocks(), s.capacity_blocks()),
            ]);
            t.row(&["affinity spills".into(), format!("{spills}/{routed}")]);
            t.row(&["warm templates tracked".into(), format!("{}", warmth.len())]);
        }
        None => t.row(&["shared store".into(), "off".into()]),
    }
    t.print();
    Ok(())
}
