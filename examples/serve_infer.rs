//! Standalone inference-engine benchmark: continuous batching under a prompt
//! stream, reporting serving-style latency/throughput (the vLLM-substrate
//! half of the system in isolation).
//!
//! `--group G` replays each prompt G times (GRPO-style grouped traffic, or a
//! serving workload with repeated prompts): with the shared-prefix KV cache
//! enabled, only the first occurrence runs the compiled prefill and the
//! report shows the cache hit rate and skipped prefills.
//!
//! ```bash
//! cargo run --release --example serve_infer -- --config configs/tiny.json --requests 64
//! cargo run --release --example serve_infer -- --config configs/tiny.json --requests 64 --group 8
//! ```

use pa_rl::config::Config;
use pa_rl::data::DataLoader;
use pa_rl::engine::{Engine, GenRequest};
use pa_rl::runtime::Runtime;
use pa_rl::util::bench::Table;
use pa_rl::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let config_path = args.str_or("config", "configs/tiny.json");
    let n_requests = args.usize_or("requests", 64);
    let group = args.usize_or("group", 1).max(1);
    let seed = args.u64_or("seed", 0);

    let cfg = Config::load(Path::new(&config_path))?;
    let artifacts = cfg.artifacts_dir();
    let rt = Runtime::load_validated(Path::new(&artifacts), &cfg)?;
    let mut eager = vec!["init", "prefill", "decode"];
    if cfg.engine.prefix_cache
        && cfg.engine.chunked_prefill
        && rt.manifest().artifacts.contains_key("prefill_chunk")
    {
        // Compile ahead of the timed region so the first partial-prefix
        // admission doesn't absorb a JIT compile into the latency numbers.
        eager.push("prefill_chunk");
    }
    rt.prepare(&eager)?;
    let params = rt.init_params(seed as i32)?;
    let mut engine = Engine::new(cfg.clone(), rt, seed);
    engine.set_weights(&params)?;

    let mut loader = DataLoader::new(cfg.data.clone());
    let n_unique = n_requests.div_ceil(group);
    let prompts = loader.next_batch(n_unique);
    // Grouped traffic: a prompt's repeats are adjacent, like the
    // coordinator's group-affine dispatch.
    let reqs: Vec<GenRequest> = (0..n_requests)
        .map(|i| GenRequest { request_id: i as u64, prompt: prompts[i / group].tokens.clone() })
        .collect();

    let t0 = std::time::Instant::now();
    let results = engine.generate_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = results.iter().map(|r| r.seconds).collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() as f64 - 1.0) * p).round() as usize];
    let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let finished = results
        .iter()
        .filter(|r| r.tokens.last() == Some(&pa_rl::data::EOS))
        .count();

    let mut t = Table::new(
        "Inference engine: continuous batching benchmark",
        &["Metric", "Value"],
    );
    t.row(&["requests".into(), format!("{n_requests}")]);
    t.row(&["group size".into(), format!("{group}")]);
    t.row(&["slots".into(), format!("{}", cfg.engine.n_slots)]);
    t.row(&["decode chunk".into(), format!("{}", cfg.engine.decode_chunk)]);
    t.row(&["wall (s)".into(), format!("{wall:.3}")]);
    t.row(&["generated tokens".into(), format!("{total_tokens}")]);
    t.row(&["tokens / s".into(), format!("{:.1}", total_tokens as f64 / wall)]);
    t.row(&["requests / s".into(), format!("{:.2}", n_requests as f64 / wall)]);
    t.row(&["latency p50 (s)".into(), format!("{:.3}", pct(0.5))]);
    t.row(&["latency p95 (s)".into(), format!("{:.3}", pct(0.95))]);
    t.row(&["latency max (s)".into(), format!("{:.3}", pct(1.0))]);
    t.row(&["EOS-terminated".into(), format!("{finished}/{n_requests}")]);
    t.row(&["prefills (compiled)".into(), format!("{}", engine.stats.prefills)]);
    t.row(&["prefills skipped".into(), format!("{}", engine.stats.prefills_skipped)]);
    t.row(&["prefill chunks".into(), format!("{}", engine.stats.prefill_chunks)]);
    t.row(&[
        "prefill tokens saved".into(),
        format!("{}", engine.stats.prefill_tokens_saved),
    ]);
    t.row(&["decode chunks".into(), format!("{}", engine.stats.decode_chunks)]);
    match engine.cache_stats() {
        Some(c) => {
            t.row(&["prefix cache".into(), "on".into()]);
            t.row(&["kv hit rate".into(), format!("{:.1}%", c.hit_rate() * 100.0)]);
            t.row(&[
                "prompt tokens hit/miss".into(),
                format!("{}/{}", c.hit_tokens, c.miss_tokens),
            ]);
            t.row(&["partial-prefix hits".into(), format!("{}", c.partial_hits)]);
            t.row(&["kv bytes saved".into(), format!("{}", c.bytes_saved)]);
            t.row(&["cache evictions".into(), format!("{}", c.evictions)]);
        }
        None => t.row(&["prefix cache".into(), "off".into()]),
    }
    t.print();
    Ok(())
}
