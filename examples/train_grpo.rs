//! End-to-end GRPO training driver — the repository's primary experiment
//! binary (EXPERIMENTS.md records its runs; Fig. 5's reward curves come from
//! its CSV output).
//!
//! ```bash
//! make artifacts CONFIG=configs/small.json
//! cargo run --release --example train_grpo -- \
//!     --config configs/small.json --mode async --iters 50 \
//!     --sft-warmup 30 --eval 64 --csv runs/async.csv
//! # Elastic fleet: one engine joins at iteration 2, one drains at 4.
//! cargo run --release --example train_grpo -- \
//!     --config configs/small.json --iters 8 --join iter:2 --leave iter:4
//! ```
//!
//! Stages: (1) optional SFT warmup on target answers so the policy emits
//! digits at all; (2) T iterations of Algorithm 1 in the chosen mode
//! (sync | async | stale); (3) held-out exact-match evaluation. Per-iteration
//! metrics stream to stdout and to the CSV.
//!
//! `--join iter:N[,iter:M...]` / `--leave iter:N[,...]` merge one-engine
//! fleet events into the config's `rl.fleet_schedule`: joins are
//! weight-synced before they can receive work, drains finish in-flight
//! rollouts and re-route the rest — the run stays strictly on-policy and
//! loses nothing.
//!
//! `--engines N`, `--temperature T`, and `--dump-rollouts PATH` serve the
//! placement-independence gate: override the fleet size and sampling
//! temperature, then dump every request's sampled token/logprob stream
//! (JSONL, sorted by request id). Two runs that differ only in fleet shape
//! must produce byte-identical dumps — see docs/DETERMINISM.md.

use pa_rl::config::{Config, FleetEvent};
use pa_rl::coordinator::{evaluate, Driver, DriverOpts, Mode};
use pa_rl::data::{DataLoader, TaskGen, EOS};
use pa_rl::grpo::{build_standard, Sample};
use pa_rl::metrics::CsvLog;
use pa_rl::runtime::Runtime;
use pa_rl::train::{IterStats, Trainer};
use pa_rl::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let config_path = args.str_or("config", "configs/tiny.json");
    let mode = Mode::parse(&args.str_or("mode", "async"))?;
    let spa = args.has_flag("spa") || args.get("spa").is_some_and(|v| v == "true");
    let iters = args.u64_or("iters", 10);
    let sft_warmup = args.usize_or("sft-warmup", 0);
    let eval_n = args.usize_or("eval", 0);
    let seed = args.u64_or("seed", 0);
    let csv_path = args.get("csv").map(PathBuf::from);
    let dump_rollouts = args.get("dump-rollouts").map(PathBuf::from);

    let mut cfg = Config::load(Path::new(&config_path))?;
    // --engines N / --temperature T override the config so the determinism
    // gate (scripts/determinism_gate.sh) can diff rollout streams across
    // fleet shapes without per-shape config files (docs/DETERMINISM.md).
    if let Some(n) = args.get("engines") {
        cfg.rl.n_engines = n
            .parse()
            .map_err(|_| anyhow::anyhow!("--engines expects an integer, got '{n}'"))?;
    }
    if let Some(t) = args.get("temperature") {
        cfg.engine.temperature = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--temperature expects a float, got '{t}'"))?;
    }
    // --metrics basic|full overrides the config's telemetry level (full
    // stamps request timelines and writes per-iteration snapshots under
    // artifacts/runs/<name>/ — see docs/OBSERVABILITY.md).
    if let Some(level) = args.get("metrics") {
        cfg.metrics.level = pa_rl::metrics::MetricsLevel::parse(&level)
            .ok_or_else(|| anyhow::anyhow!("--metrics expects basic|full, got '{level}'"))?;
    }
    // --join iter:N / --leave iter:N (comma-separated for several) merge
    // into the config's fleet schedule, one engine per entry.
    for (flag, is_join) in [("join", true), ("leave", false)] {
        let Some(spec) = args.get(flag) else { continue };
        for part in spec.split(',') {
            let iter: u64 = part
                .trim()
                .strip_prefix("iter:")
                .and_then(|n| n.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("--{flag} expects iter:N, got '{part}'"))?;
            cfg.rl.fleet_schedule.push(FleetEvent {
                iter,
                join: usize::from(is_join),
                leave: usize::from(!is_join),
            });
        }
    }
    cfg.rl.fleet_schedule.sort_by_key(|e| e.iter);
    cfg.rl.validate_fleet_schedule()?;
    let artifacts = PathBuf::from(cfg.artifacts_dir());
    eprintln!(
        "[train_grpo] config={} mode={mode:?} spa={spa} iters={iters} sft={sft_warmup} params={}",
        cfg.name,
        cfg.model.param_count()
    );
    if !cfg.rl.fleet_schedule.is_empty() {
        eprintln!("[train_grpo] fleet schedule: {:?}", cfg.rl.fleet_schedule);
    }

    // ---- optional SFT warmup -------------------------------------------
    let warm = if sft_warmup > 0 {
        Some(run_sft_warmup(&cfg, &artifacts, sft_warmup, seed as i32)?)
    } else {
        None
    };

    // ---- RL -------------------------------------------------------------
    let opts = DriverOpts { mode, spa, seed };
    let mut driver = Driver::new(cfg.clone(), &artifacts, opts)?;
    // --dump-rollouts PATH records every (request_id, tokens, logprobs)
    // triple and writes them sorted by request id after the run — two runs
    // with different fleet shapes must produce byte-identical files
    // (docs/DETERMINISM.md describes the oracle-diff recipe).
    if dump_rollouts.is_some() {
        driver.record_rollouts(true);
    }
    if let Some(params) = warm {
        driver.set_policy(params)?;
    }
    if eval_n > 0 {
        let before = evaluate(&cfg, &artifacts, driver.trainer().policy(), eval_n)?;
        println!("eval before RL: accuracy {:.3} ({} / {})", before.accuracy, before.correct, before.n);
    }

    // Full telemetry appends the phase-attribution columns; the basic header
    // (and every row) stays byte-identical to the pre-attribution CSV.
    let full = cfg.metrics.level.is_full();
    let mut csv_header = vec!["iter", "reward", "loss", "kl", "entropy", "grad_norm",
                              "wall_s", "consumer_wait_s", "train_tokens", "staleness",
                              "kv_hit_rate", "prefill_tokens_saved",
                              "cross_engine_hits", "cross_engine_tokens",
                              "store_publishes", "affinity_spills", "engines"];
    if full {
        csv_header.extend(["producer_idle_s", "sync_overhead_s", "useful_compute_s",
                           "pipeline_efficiency"]);
    }
    let mut csv = csv_path.as_ref().map(|p| CsvLog::new(p, &csv_header));
    let t0 = std::time::Instant::now();
    let report = {
        let mut iters_done = Vec::new();
        for t in 0..iters {
            let rep = driver.run(1)?;
            let it = &rep.iters[0];
            println!(
                "iter {t:>3}  reward {:>6.3}  loss {:>9.5}  kl {:>8.5}  wall {:>6.2}s  wait {:>5.2}s  tokens {:>7}  stale {:.2}  kv-hit {:>4.0}%  engines {:>2}",
                it.reward_mean, it.stats.loss, it.stats.kl, it.wall_seconds,
                it.consumer_wait_seconds, it.train_input_tokens, it.staleness_mean,
                it.kv_hit_rate * 100.0, it.engines,
            );
            if it.engines_joined + it.engines_left > 0 {
                println!(
                    "         fleet resize: +{} joined, -{} drained -> {} engines",
                    it.engines_joined, it.engines_left, it.engines
                );
            }
            // Full-telemetry runs carry per-request latency distributions;
            // basic runs have None here and print exactly the seed's lines.
            if let Some(req) = &it.requests {
                println!("         requests: {}", req.summary());
            }
            if full {
                // Bubble attribution (docs/OBSERVABILITY.md): where the
                // iteration's deployed device-seconds went.
                let p = &it.phases;
                println!(
                    "         phases: idle {:>5.2}s  wait {:>5.2}s  sync {:>5.2}s  useful {:>6.2}s  efficiency {:>4.1}%",
                    p.producer_idle_s, p.consumer_wait_s, p.sync_overhead_s,
                    p.useful_compute_s, p.pipeline_efficiency * 100.0,
                );
            }
            if let Some(c) = csv.as_mut() {
                let mut row = vec![
                    t as f64,
                    it.reward_mean,
                    it.stats.loss,
                    it.stats.kl,
                    it.stats.entropy,
                    it.stats.grad_norm,
                    it.wall_seconds,
                    it.consumer_wait_seconds,
                    it.train_input_tokens as f64,
                    it.staleness_mean,
                    it.kv_hit_rate,
                    it.prefill_tokens_saved as f64,
                    it.cross_engine_hits as f64,
                    it.cross_engine_tokens as f64,
                    it.store_publishes as f64,
                    it.affinity_spills as f64,
                    it.engines as f64,
                ];
                if full {
                    row.extend([
                        it.phases.producer_idle_s,
                        it.phases.sync_overhead_s,
                        it.phases.useful_compute_s,
                        it.phases.pipeline_efficiency,
                    ]);
                }
                c.add(&row);
            }
            iters_done.push(it.clone());
        }
        iters_done
    };
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = report.iter().map(|i| i.train_input_tokens).sum();
    // Peak fleet + trainer (equals the static fleet when no schedule ran).
    let devices = report.iter().map(|i| i.engines).max().unwrap_or(cfg.rl.n_engines) + 1;
    println!(
        "\nTOTAL: {tokens} train tokens in {wall:.1}s on {devices} instances -> TPSPD {:.3}",
        tokens as f64 / (wall * devices as f64)
    );
    if let Some(c) = csv.as_mut() {
        c.flush()?;
        println!("curve written to {}", csv_path.unwrap().display());
    }
    if let Some(path) = &dump_rollouts {
        // Engine index is deliberately omitted: it is placement metadata and
        // the one field allowed to differ between fleet shapes. f32 Display
        // is shortest-roundtrip, so equal bytes <=> equal bits.
        let records = driver.take_rollout_records();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::new();
        for r in &records {
            let toks: Vec<String> = r.tokens.iter().map(|t| t.to_string()).collect();
            // f32 Display renders NaN/inf as bare tokens that are not valid
            // JSON; a poisoned rollout must not make the whole dump
            // unparseable.
            let lps: Vec<String> = r
                .logprobs
                .iter()
                .map(|l| if l.is_finite() { l.to_string() } else { "null".to_string() })
                .collect();
            out.push_str(&format!(
                "{{\"request_id\":{},\"weight_version\":{},\"tokens\":[{}],\"logprobs\":[{}]}}\n",
                r.request_id,
                r.weight_version,
                toks.join(","),
                lps.join(",")
            ));
        }
        std::fs::write(path, out)?;
        println!("rollout streams ({} records) written to {}", records.len(), path.display());
    }
    if eval_n > 0 {
        let after = evaluate(&cfg, &artifacts, driver.trainer().policy(), eval_n)?;
        println!("eval after RL: accuracy {:.3} ({} / {})", after.accuracy, after.correct, after.n);
    }
    println!("\n{}", driver.trace().render_ascii(100));
    // Full telemetry: the driver already refreshed the Perfetto-loadable
    // span-tree export at the end of each run() call; surface its path.
    if let Some(path) = driver.write_trace_json()? {
        println!("perfetto trace: {} (load in https://ui.perfetto.dev)", path.display());
    }
    Ok(())
}

/// Supervised warmup: train on (prompt -> correct answer + EOS) pairs so the
/// random-init policy produces parseable digit answers before RL begins.
fn run_sft_warmup(
    cfg: &Config,
    artifacts: &Path,
    steps: usize,
    seed: i32,
) -> anyhow::Result<pa_rl::runtime::HostParams> {
    eprintln!("[train_grpo] SFT warmup: {steps} steps");
    let rt = Runtime::load_validated(artifacts, cfg)?;
    rt.prepare(&["init", "sft_step", "adam_update"])?;
    let mut trainer = Trainer::new(cfg.clone(), rt, seed)?;
    let mut loader = DataLoader::new(cfg.data.clone());
    for step in 0..steps {
        trainer.begin_iteration()?;
        let prompts = loader.next_batch(cfg.train.micro_bs);
        let targets: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| {
                let mut t = loader
                    .taskgen()
                    .tokenizer()
                    .encode(&TaskGen::target_response(p.answer))
                    .expect("answers tokenize");
                t.push(EOS);
                t
            })
            .collect();
        let samples: Vec<Sample> = prompts
            .iter()
            .zip(&targets)
            .map(|(p, t)| Sample { prompt: &p.tokens, response: t, advantage: 0.0 })
            .collect();
        let batch = build_standard(&samples, cfg.train.micro_bs, cfg.train.seq_len);
        let loss = trainer.sft_micro(&batch)?;
        let mut stats = IterStats::default();
        trainer.end_iteration(&mut stats)?;
        if step % 10 == 0 || step + 1 == steps {
            eprintln!("  sft step {step:>4}  loss {loss:.4}");
        }
    }
    let mut params = trainer.policy().clone();
    params.version = 0; // RL restarts version numbering from the warm start
    Ok(params)
}
