//! Reproduce a paper table at cluster scale via the discrete-event simulator.
//!
//! ```bash
//! cargo run --release --example simulate_cluster -- --table 1
//! cargo run --release --example simulate_cluster -- --table all --iters 5
//! ```

use pa_rl::sim::experiments;
use pa_rl::util::bench::{f3, fx, Table};
use pa_rl::util::cli::Args;

fn print_rows(title: &str, rows: &[experiments::Row]) {
    let base = rows.last().map(|r| (&r.sim, r.paper_tpspd)).unwrap();
    let mut t = Table::new(
        title,
        &["Setting", "Paper TPSPD", "Sim TPSPD", "Paper async/x", "Sim async/x", "T_inf (s)", "T_train (s)"],
    );
    for r in rows {
        let paper_factor = match (base.1, r.paper_tpspd) {
            (Some(a), Some(x)) if x > 0.0 => fx(a / x),
            _ => "-".into(),
        };
        let sim_factor = fx(base.0.tpspd / r.sim.tpspd);
        t.row(&[
            r.setting.clone(),
            r.paper_tpspd.map(f3).unwrap_or_else(|| "-".into()),
            f3(r.sim.tpspd),
            paper_factor,
            sim_factor,
            format!("{:.0}", r.sim.t_infer_mean),
            format!("{:.0}", r.sim.t_train_mean),
        ]);
    }
    t.note("absolute TPSPD is testbed-dependent; the async/x win-factors are the reproduction target");
    t.print();
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let which = args.str_or("table", "all");
    let iters = args.usize_or("iters", 3);

    if which == "1" || which == "all" {
        print_rows("Table 1 — Qwen3-8B, DeepScaleR, 16 NPUs, 16K ctx", &experiments::table1(iters));
    }
    if which == "2" || which == "all" {
        let (g1, g2) = experiments::table2(iters);
        print_rows("Table 2 (group 1) — 32B, 16K ctx, GBS 32", &g1);
        print_rows("Table 2 (group 2) — 32B, 8K ctx, GBS 64, 64 NPUs", &g2);
    }
    if which == "3" || which == "all" {
        print_rows("Table 3 — Qwen2.5-7B, GSM8K, 1K ctx (SPA ablation)", &experiments::table3(iters));
    }
    if which == "4" || which == "all" {
        print_rows("Table 4 — Qwen2.5-1.5B, GSM8K, 8xA100", &experiments::table4(iters));
    }
    if which == "prefix" || which == "all" {
        print_rows(
            "Prefix-cache ablation — Qwen2.5-7B, GSM8K, engine KV prefix cache off/on",
            &experiments::prefix_cache_ablation(iters),
        );
    }
    if which == "5" || which == "all" {
        let rows = experiments::table5(iters);
        let mut t = Table::new(
            "Table 5 / Fig. 6 — scalability (Qwen3-8B, DeepScaleR)",
            &["NPUs", "Paper TPSPD", "Sim TPSPD", "Paper total tok/s", "Sim total tok/s"],
        );
        for (n, paper, sim) in &rows {
            t.row(&[
                format!("{n}"),
                paper.map(f3).unwrap_or_else(|| "-".into()),
                f3(sim.tpspd),
                paper.map(|p| f3(p * *n as f64)).unwrap_or_else(|| "-".into()),
                f3(sim.tpspd * *n as f64),
            ]);
        }
        t.note("near-linear total-throughput scaling; per-device TPSPD declines with inter-node comm");
        t.print();
    }
    Ok(())
}
