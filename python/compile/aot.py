"""AOT pipeline: lower every compiled computation to HLO text + manifest.

Usage:
    python -m compile.aot --config ../configs/small.json [--out DIR]
                          [--attn-impl jnp|pallas] [--force]

Emits into ``artifacts/<config name>/``:
  * one ``<artifact>.hlo.txt`` per compiled computation (HLO *text*, not a
    serialized HloModuleProto: jax >= 0.5 emits 64-bit instruction ids that
    the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns
    ids and round-trips cleanly — see /opt/xla-example/README.md);
  * ``manifest.json`` describing the parameter table, every artifact's input/
    output signature, and the resolved config — the rust runtime refuses to
    run against a manifest that disagrees with its own config resolution.

Python runs only here, at build time. The rust binary is self-contained
afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import config as cfgmod
from . import model

# v5: decode takes per-slot `seeds` [n_slots] i32 (one per request stream)
# instead of a scalar `seed` — the placement-independent sampling change.
MANIFEST_VERSION = 5


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals, names):
    out = []
    for name, a in zip(names, avals):
        out.append({"name": name, "shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def _param_names(prefix=""):
    return [f"{prefix}{n}" for n in model.PARAM_NAMES]


def artifact_specs(cfg, attn_impl):
    """name -> (fn, example_args, input_names, output_names)."""
    n = model.PARAM_NAMES
    shapes = model.param_shapes(cfg)
    f32 = jax.numpy.float32
    i32 = jax.numpy.int32
    params_spec = [jax.ShapeDtypeStruct(shapes[nm], f32) for nm in n]

    batch_names = ["tokens", "labels", "pos", "seg", "adv", "weight", "prompt_len"]
    metric_names = list(model.TRAIN_METRICS)

    specs = {}
    specs["init"] = (
        lambda seed: model.init_params(cfg, seed),
        [jax.ShapeDtypeStruct((), i32)],
        ["seed"],
        _param_names(),
    )
    specs["train_step"] = (
        model.make_train_step(cfg, spa=False, attn_impl="jnp"),
        model.train_step_example_args(cfg, spa=False),
        _param_names("policy.") + _param_names("old.") + _param_names("ref.") + batch_names,
        [f"grad.{nm}" for nm in n] + metric_names,
    )
    specs["train_step_spa"] = (
        model.make_train_step(cfg, spa=True, attn_impl=attn_impl),
        model.train_step_example_args(cfg, spa=True),
        _param_names("policy.") + _param_names("old.") + _param_names("ref.") + batch_names,
        [f"grad.{nm}" for nm in n] + metric_names,
    )
    specs["sft_step"] = (
        model.make_sft_step(cfg),
        model.sft_step_example_args(cfg),
        _param_names() + ["tokens", "labels", "pos", "seg", "weight"],
        [f"grad.{nm}" for nm in n] + ["loss"],
    )
    specs["logprob_eval"] = (
        model.make_logprob_eval(cfg),
        model.logprob_eval_example_args(cfg),
        _param_names() + ["tokens", "labels", "pos", "seg"],
        ["logprobs"],
    )
    specs["prefill"] = (
        model.make_prefill(cfg),
        model.prefill_example_args(cfg),
        _param_names() + ["kv", "slot", "tokens", "length"],
        ["kv", "logits"],
    )
    specs["prefill_chunk"] = (
        model.make_prefill_chunk(cfg),
        model.prefill_chunk_example_args(cfg),
        _param_names() + ["kv", "slot", "tokens", "start", "length"],
        ["kv", "logits"],
    )
    specs["decode"] = (
        model.make_decode(cfg),
        model.decode_example_args(cfg),
        _param_names() + ["kv", "tokens", "pos", "active", "seeds", "temperature", "top_p"],
        ["kv", "tokens", "logprobs", "pos", "active"],
    )
    specs["adam_update"] = (
        model.make_adam(cfg),
        model.adam_example_args(cfg),
        _param_names("p.") + _param_names("g.") + _param_names("m.") + _param_names("v.") + ["step"],
        _param_names("p.") + _param_names("m.") + _param_names("v.") + ["grad_norm"],
    )
    return specs


def config_fingerprint(cfg, attn_impl):
    blob = json.dumps(cfgmod.dump_resolved(cfg), sort_keys=True) + attn_impl + str(MANIFEST_VERSION)
    src_dir = os.path.dirname(os.path.abspath(__file__))
    for fname in sorted(os.listdir(src_dir)):
        if fname.endswith(".py"):
            with open(os.path.join(src_dir, fname), "rb") as f:
                blob += hashlib.sha256(f.read()).hexdigest()
    kdir = os.path.join(src_dir, "kernels")
    for fname in sorted(os.listdir(kdir)):
        if fname.endswith(".py"):
            with open(os.path.join(kdir, fname), "rb") as f:
                blob += hashlib.sha256(f.read()).hexdigest()
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def build(config_path, out_dir=None, attn_impl="jnp", force=False, only=None):
    cfg = cfgmod.load_config(config_path)
    out_dir = out_dir or os.path.join("..", "artifacts", cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fp = config_fingerprint(cfg, attn_impl)

    if not force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fp and all(
                os.path.exists(os.path.join(out_dir, a["file"]))
                for a in old.get("artifacts", {}).values()
            ):
                print(f"[aot] {cfg.name}: artifacts fresh (fingerprint {fp}), skipping")
                return manifest_path
        except (json.JSONDecodeError, KeyError):
            pass

    specs = artifact_specs(cfg, attn_impl)
    manifest_artifacts = {}
    for name, (fn, example_args, in_names, out_names) in specs.items():
        if only and name not in only:
            continue
        print(f"[aot] lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # output signature from the jax trace
        out_avals = jax.eval_shape(fn, *example_args)
        flat_out = jax.tree_util.tree_leaves(out_avals)
        manifest_artifacts[name] = {
            "file": fname,
            "inputs": _sig(example_args, in_names),
            "outputs": _sig(flat_out, out_names),
        }
        print(f"[aot]   wrote {fname} ({len(text)} chars)")

    shapes = model.param_shapes(cfg)
    manifest = {
        "version": MANIFEST_VERSION,
        "fingerprint": fp,
        "attn_impl": attn_impl,
        "config": cfgmod.dump_resolved(cfg),
        "param_count": int(model.param_count(cfg)),
        "params": [
            {"name": nm, "shape": list(shapes[nm]), "dtype": "float32"}
            for nm in model.PARAM_NAMES
        ],
        "kv_cache": {"shape": list(model.kv_cache_shape(cfg)), "dtype": "float32"},
        "artifacts": manifest_artifacts,
        "special_tokens": {"pad": model.PAD_ID, "bos": model.BOS_ID, "eos": model.EOS_ID},
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {manifest_path}")
    return manifest_path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", required=True)
    ap.add_argument("--out", default=None)
    ap.add_argument("--attn-impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", nargs="*", default=None, help="subset of artifacts")
    args = ap.parse_args()
    build(args.config, args.out, args.attn_impl, args.force, args.only)


if __name__ == "__main__":
    sys.exit(main())
