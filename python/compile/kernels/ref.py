"""Pure-jnp oracles for the L1 kernels.

These reference implementations define the *semantics* that the Pallas kernels
must match bit-for-bit (to float tolerance):

* the shared-prompt attention mask (paper Fig. 4, extended with the
  duplicated-last-prompt-token layout — see rust/src/grpo/batch.rs for the
  packing contract and the exactness argument);
* masked multi-head attention with grouped-query KV heads;
* fused log-softmax label gather.

They are also what the AOT'd train-step artifacts use by default
(``attn_impl="jnp"``): XLA fuses the dense-mask attention well on CPU, while
the Pallas kernel (interpret mode) exists to express and validate the TPU
block schedule. pytest sweeps assert kernel == ref on randomized shapes.
"""

import jax.numpy as jnp
from jax import nn


def spa_mask(seg, pos, prompt_len):
    """Shared-prompt attention mask.

    Args:
      seg: [S] int32 segment ids: -1 padding, 0 shared prompt, 1..K responses.
      pos: [S] int32 rope positions (responses restart at prompt_len - 1,
        the duplicated-last-prompt-token position).
      prompt_len: scalar int32, length of the shared prompt (Lp).

    Returns:
      [S, S] bool; True where query i may attend key j.

    Rules (see DESIGN.md and rust/src/grpo/batch.rs):
      * prompt token i: attends prompt tokens j <= i (standard causal);
      * response token i in segment k: attends prompt keys with
        pos_j < Lp - 1 (the original last prompt token is *excluded*; its
        role is played by the segment's own duplicated first token), plus
        its own segment's tokens j <= i;
      * padding: attends only itself (keeps softmax finite; output unused).
    """
    s = seg.shape[0]
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    seg_i = seg[:, None]
    seg_j = seg[None, :]
    causal_same = (seg_i == seg_j) & (j <= i) & (seg_i >= 0)
    prompt_key = (seg_i >= 1) & (seg_j == 0) & (pos[None, :] < prompt_len - 1)
    pad_self = (seg_i < 0) & (i == j)
    return causal_same | prompt_key | pad_self


def causal_mask(s):
    """[S, S] standard causal mask."""
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    return j <= i


def repeat_kv(x, n_rep):
    """[B, Hk, S, Dh] -> [B, Hk * n_rep, S, Dh] (GQA key/value sharing)."""
    if n_rep == 1:
        return x
    b, hk, s, dh = x.shape
    x = jnp.broadcast_to(x[:, :, None], (b, hk, n_rep, s, dh))
    return x.reshape(b, hk * n_rep, s, dh)


def attention_ref(q, k, v, mask):
    """Masked MHA oracle.

    Args:
      q: [B, Hq, S, Dh]; k, v: [B, Hk, S, Dh] with Hq % Hk == 0.
      mask: broadcastable to [B, Hq, S, S] bool.

    Returns: [B, Hq, S, Dh].
    """
    n_rep = q.shape[1] // k.shape[1]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    probs = nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def logprob_gather_ref(logits, labels):
    """Per-position log-probability of the label token.

    Args:
      logits: [..., V] float; labels: [...] int32.
    Returns: [...] float = log_softmax(logits)[..., labels].
    """
    lse = nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return picked - lse
