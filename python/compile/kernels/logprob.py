"""L1 Pallas kernel: fused log-softmax + label gather.

The tri-model train step needs per-token label log-probabilities three times
(policy, old-policy, reference). Materialising three [T, V] log-softmax
tensors is pure HBM waste; this kernel fuses the reduction and the gather so
only the [T] result leaves the tile. Validated against
:func:`ref.logprob_gather_ref` by the pytest sweeps.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logprob_kernel(logits_ref, labels_ref, o_ref):
    logits = logits_ref[...].astype(jnp.float32)  # [bt, V]
    labels = labels_ref[...]  # [bt]
    m = logits.max(axis=-1)
    lse = m + jnp.log(jnp.exp(logits - m[:, None]).sum(axis=-1))
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    o_ref[...] = (picked - lse).astype(o_ref.dtype)


def logprob_gather(logits, labels, *, block_t=64, interpret=True):
    """Per-position label log-probabilities.

    Args:
      logits: [T, V] float; labels: [T] integer.
      block_t: rows per program; T must be divisible (clamped to T).
    Returns: [T] float32 log p(label).
    """
    t, v = logits.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, f"T={t} must be divisible by block_t={block_t}"
    return pl.pallas_call(
        _logprob_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, v), lambda i: (i, 0)),
            pl.BlockSpec((block_t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=interpret,
    )(logits, labels.astype(jnp.int32))
