"""L1 Pallas kernel: shared-prompt attention (paper §4.3, Fig. 4).

A flash-attention-style blockwise kernel whose mask understands the
shared-prompt packed layout: one GRPO group packed as
``[prompt, response_1, ..., response_K]`` with segment ids, where each
response attends the shared prompt plus its own tokens only. Cross-response
blocks are *fully masked* and the kernel skips them — this is the TPU-shaped
expression of the paper's redundancy elimination: the prompt's K/V tiles are
streamed from HBM into VMEM once per query block instead of K times, and the
(response_i × response_j, i≠j) tiles never leave HBM at all.

Hardware adaptation (DESIGN.md §3): the paper fuses a custom mask into NPU
``npu_fusion_attention`` / GPU FlashAttention; on TPU the same insight maps to
a Pallas BlockSpec schedule — Q/K/V tiles staged through VMEM, the running
softmax in registers, masks evaluated per tile so masked tiles are skipped
before their matmuls reach the MXU. The kernel runs under ``interpret=True``
in this repository (the CPU PJRT plugin cannot execute Mosaic custom-calls);
the pytest suite asserts exact agreement with :mod:`ref` and the estimated
VMEM/MXU numbers are tabulated in DESIGN.md §Perf.

The same kernel also serves standard causal attention: with all segment ids 0
the mask degenerates to causal, which the tests exercise too.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_mask(qi, kj, seg_q, seg_k, pos_k, prompt_len):
    """Mask for a (block_q, block_k) tile.

    qi/kj: [bq]/[bk] global indices; seg_q/seg_k: segment ids; pos_k: rope
    positions of keys; prompt_len: scalar Lp. Semantics match ref.spa_mask.
    """
    i = qi[:, None]
    j = kj[None, :]
    seg_i = seg_q[:, None]
    seg_j = seg_k[None, :]
    causal_same = (seg_i == seg_j) & (j <= i) & (seg_i >= 0)
    prompt_key = (seg_i >= 1) & (seg_j == 0) & (pos_k[None, :] < prompt_len - 1)
    pad_self = (seg_i < 0) & (i == j)
    return causal_same | prompt_key | pad_self


def _spa_kernel(seg_ref, pos_ref, plen_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, scale):
    """One (batch, head, q-block) program: flash attention over key tiles."""
    bq, dh = q_ref.shape[2], q_ref.shape[3]
    s = k_ref.shape[2]
    n_kblocks = s // block_k

    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # [bq, dh]
    qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0)
    seg_all = seg_ref[...]
    pos_all = pos_ref[...]
    plen = plen_ref[0]
    seg_q = jax.lax.dynamic_slice(seg_all, (iq * bq,), (bq,))

    def body(jk, carry):
        m_prev, l_prev, acc = carry
        start = jk * block_k
        kj = start + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
        seg_k = jax.lax.dynamic_slice(seg_all, (start,), (block_k,))
        pos_k = jax.lax.dynamic_slice(pos_all, (start,), (block_k,))
        mask = _tile_mask(qi, kj, seg_q, seg_k, pos_k, plen)

        def live(_):
            k_blk = k_ref[0, 0, pl.ds(start, block_k), :].astype(jnp.float32)
            v_blk = v_ref[0, 0, pl.ds(start, block_k), :].astype(jnp.float32)
            scores = q @ k_blk.T * scale  # [bq, bk]
            scores = jnp.where(mask, scores, -1e30)
            m_new = jnp.maximum(m_prev, scores.max(axis=1))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(scores - m_new[:, None])
            l_new = l_prev * alpha + p.sum(axis=1)
            acc_new = acc * alpha[:, None] + p @ v_blk
            return m_new, l_new, acc_new

        def skip(_):
            return m_prev, l_prev, acc

        # Tile-level sparsity: fully-masked tiles (e.g. response_i keys for a
        # response_j query block, or prompt queries vs response keys) skip both
        # the HBM->VMEM loads and the MXU matmuls.
        return jax.lax.cond(jnp.any(mask), live, skip, operand=None)

    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))
    # Every row attends at least itself (pad rows self-attend), so l > 0.
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def spa_attention(q, k, v, seg, pos, prompt_len, *, block_q=32, block_k=32, interpret=True):
    """Shared-prompt attention.

    Args:
      q: [B, Hq, S, Dh]; k, v: [B, Hk, S, Dh] (Hq % Hk == 0).
      seg: [S] int32 (-1 pad / 0 prompt / 1..K responses).
      pos: [S] int32 rope positions.
      prompt_len: scalar int32 (Lp).
      block_q, block_k: tile sizes; S must be divisible by both (clamped to S).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns: [B, Hq, S, Dh], matching ``ref.attention_ref(q, k, v,
      ref.spa_mask(seg, pos, prompt_len))``.

    Differentiable: the forward pass is the Pallas kernel; the backward pass
    is the exact dense-reference VJP (recompute-from-residuals, the standard
    first deployment shape for flash-style kernels — a dedicated backward
    kernel is the TODO the paper's npu_fusion_attention also hides).
    """
    from . import ref as kref  # local import to keep module load cheap

    seg = seg.astype(jnp.int32)
    pos = pos.astype(jnp.int32)
    prompt_len = jnp.asarray(prompt_len, jnp.int32)

    @jax.custom_vjp
    def attn(q, k, v):
        return _spa_forward(q, k, v, seg, pos, prompt_len, block_q, block_k, interpret)

    def attn_fwd(q, k, v):
        return attn(q, k, v), (q, k, v)

    def attn_bwd(res, g):
        q, k, v = res
        mask = kref.spa_mask(seg, pos, prompt_len)[None, None]
        _, vjp = jax.vjp(lambda a, b, c: kref.attention_ref(a, b, c, mask), q, k, v)
        return vjp(g)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn(q, k, v)


def _spa_forward(q, k, v, seg, pos, prompt_len, block_q, block_k, interpret):
    b, hq, s, dh = q.shape
    hk = k.shape[1]
    assert hq % hk == 0, "query heads must be a multiple of kv heads"
    n_rep = hq // hk
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (
        f"seq len {s} must be divisible by block sizes ({block_q}, {block_k})"
    )
    plen = jnp.reshape(prompt_len.astype(jnp.int32), (1,))

    grid = (b, hq, s // block_q)
    kernel = functools.partial(
        _spa_kernel, block_k=block_k, scale=1.0 / (dh**0.5)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s,), lambda bi, h, iq: (0,)),
            pl.BlockSpec((s,), lambda bi, h, iq: (0,)),
            pl.BlockSpec((1,), lambda bi, h, iq: (0,)),
            pl.BlockSpec((1, 1, block_q, dh), lambda bi, h, iq: (bi, h, iq, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, h, iq, _n=n_rep: (bi, h // _n, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bi, h, iq, _n=n_rep: (bi, h // _n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda bi, h, iq: (bi, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(seg.astype(jnp.int32), pos.astype(jnp.int32), plen, q, k, v)


def causal_attention(q, k, v, *, block_q=32, block_k=32, interpret=True):
    """Standard causal attention via the same kernel (all segments = 0)."""
    s = q.shape[2]
    seg = jnp.zeros((s,), jnp.int32)
    pos = jnp.arange(s, dtype=jnp.int32)
    # prompt_len = 0 disables the cross-segment prompt rule entirely.
    return spa_attention(
        q, k, v, seg, pos, jnp.asarray(0, jnp.int32),
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def vmem_estimate_bytes(s, dh, block_q, block_k, dtype_bytes=2):
    """Estimated VMEM working set per program for the TPU lowering:
    q tile + k tile + v tile + accumulators (f32). Used by DESIGN.md §Perf."""
    q_tile = block_q * dh * dtype_bytes
    kv_tiles = 2 * block_k * dh * dtype_bytes
    acc = block_q * dh * 4 + 2 * block_q * 4
    meta = 2 * s * 4  # seg/pos vectors
    return q_tile + kv_tiles + acc + meta


def mxu_tile_utilization(block_q, block_k, dh, mxu=128):
    """Fraction of MXU systolic-array slots filled by the kernel's two matmuls
    (q@k^T and p@v) at the given tile shape. 1.0 when tiles are multiples of
    the 128x128 array."""
    def frac(n):
        return n / (((n + mxu - 1) // mxu) * mxu)

    return min(frac(block_q), frac(block_k), frac(dh))
