"""Config loading for the AOT pipeline.

Reads the same JSON files under ``configs/`` as the rust side
(``rust/src/config.rs``) and applies the same defaulting rules; the emitted
``manifest.json`` echoes the resolved values so the rust loader can verify
both sides agree before touching any artifact.
"""

import json
import math
from types import SimpleNamespace


def _ns(**kw):
    return SimpleNamespace(**kw)


def load_config(path):
    with open(path) as f:
        raw = json.load(f)
    return resolve(raw)


def resolve(raw):
    m = raw["model"]
    model = _ns(
        vocab_size=m["vocab_size"],
        d_model=m["d_model"],
        n_layers=m["n_layers"],
        n_heads=m["n_heads"],
        n_kv_heads=m.get("n_kv_heads", m["n_heads"]),
        d_ff=m["d_ff"],
        rope_theta=m.get("rope_theta", 10000.0),
        rmsnorm_eps=m.get("rmsnorm_eps", 1e-5),
    )
    assert model.d_model % model.n_heads == 0
    assert model.n_heads % model.n_kv_heads == 0
    model.head_dim = model.d_model // model.n_heads

    e = raw["engine"]
    engine = _ns(
        n_slots=e.get("n_slots", 8),
        prompt_max=e["prompt_max"],
        decode_chunk=e.get("decode_chunk", 16),
        max_new=e["max_new"],
        temperature=e.get("temperature", 1.0),
        top_p=e.get("top_p", 1.0),
        top_k=e.get("top_k", 0),
        # Prefix-cache block size; also the fixed token width of the
        # `prefill_chunk` artifact. Mirrors rust's default (the largest
        # divisor of prompt_max that is <= 16).
        cache_block=e.get("cache_block", math.gcd(e["prompt_max"], 16)),
    )
    engine.cache_len = engine.prompt_max + engine.max_new
    assert engine.cache_block >= 1 and engine.prompt_max % engine.cache_block == 0, (
        f"engine.cache_block ({engine.cache_block}) must divide prompt_max "
        f"({engine.prompt_max})"
    )

    r = raw["rl"]
    rl = _ns(
        batch_prompts=r["batch_prompts"],
        group_size=r["group_size"],
        iters=r.get("iters", 10),
        n_engines=r.get("n_engines", 1),
        queue_cap=r.get("queue_cap", 64),
    )

    t = raw.get("train", {})
    spa_raw = t.get("spa", {})
    spa_k = spa_raw.get("k", rl.group_size)
    train = _ns(
        micro_bs=t.get("micro_bs", 4),
        seq_len=t.get("seq_len", engine.prompt_max + engine.max_new),
        spa_k=spa_k,
        spa_pack_len=spa_raw.get("pack_len", engine.prompt_max + spa_k * engine.max_new),
        lr=t.get("lr", 1e-4),
        beta1=t.get("beta1", 0.9),
        beta2=t.get("beta2", 0.95),
        adam_eps=t.get("adam_eps", 1e-8),
        weight_decay=t.get("weight_decay", 0.01),
        grad_clip=t.get("grad_clip", 1.0),
        kl_beta=t.get("kl_beta", 0.02),
        clip_eps_low=t.get("clip_eps_low", 0.2),
        clip_eps_high=t.get("clip_eps_high", 0.2),
    )

    return _ns(
        name=raw.get("name", "unnamed"),
        raw=raw,
        model=model,
        engine=engine,
        train=train,
        rl=rl,
    )


def tiny_test_config(**overrides):
    """A minimal config for pytest (fast to trace/execute)."""
    raw = {
        "name": "pytest-tiny",
        "model": {
            "vocab_size": 32,
            "d_model": 32,
            "n_layers": 2,
            "n_heads": 4,
            "n_kv_heads": 2,
            "d_ff": 64,
        },
        "engine": {"n_slots": 3, "prompt_max": 8, "decode_chunk": 4, "max_new": 8},
        "train": {"micro_bs": 2, "lr": 1e-3},
        "rl": {"batch_prompts": 2, "group_size": 2},
    }
    for key, val in overrides.items():
        section, _, field = key.partition(".")
        if field:
            raw[section][field] = val
        else:
            raw[section] = val
    return resolve(raw)


def dump_resolved(cfg):
    """Resolved config as a JSON-able dict (manifest echo)."""
    return {
        "name": cfg.name,
        "model": vars(cfg.model).copy(),
        "engine": vars(cfg.engine).copy(),
        "train": vars(cfg.train).copy(),
        "rl": vars(cfg.rl).copy(),
    }
