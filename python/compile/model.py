"""L2: the Qwen-mini transformer and every compiled computation pa-rl ships.

This module defines, in pure JAX (calling the L1 kernels where configured):

* the transformer forward (RMSNorm, RoPE, GQA attention, SwiGLU) with
  parameters stacked per-layer and scanned, so artifact size and compile time
  are independent of depth;
* the **unified tri-model GRPO train step** (paper Fig. 2): policy, old-policy
  and reference logits computed inside one compiled program from three
  parameter sets sharing one layout — with both attention layouts (standard
  causal and shared-prompt attention);
* the inference engine's prefill / decode-chunk steps over a slot-paged KV
  cache, with temperature/top-p/top-k sampling inside the program;
* AdamW with global-norm gradient clipping, SFT warmup step, parameter init,
  and a logprob evaluator for cross-checking the engine against the trainer.

Everything here executes exactly once per config at build time
(``make artifacts``): `aot.py` lowers these functions to HLO text which the
rust runtime loads and drives. Python never runs on the request path.
"""

import functools

import jax
import jax.numpy as jnp
from jax import nn

from .kernels import ref as kref
from .kernels.logprob import logprob_gather
from .kernels.spa_attention import spa_attention

# Token ids shared with rust/src/data/tokenizer.rs.
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

# Parameter tree: name -> shape builder. Stacked [L, ...] for per-layer
# tensors. The order here is the flattening contract with the rust runtime
# (recorded in manifest.json and asserted by its loader).
PARAM_NAMES = (
    "tok_emb",
    "ln1",
    "wq",
    "wk",
    "wv",
    "wo",
    "ln2",
    "w_gate",
    "w_up",
    "w_down",
    "ln_f",
    "lm_head",
)

LAYER_PARAMS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate", "w_up", "w_down")

# ---------------------------------------------------------------------------
# Parameters


def param_shapes(cfg):
    """name -> shape, in PARAM_NAMES order."""
    m = cfg.model
    dh = m.head_dim
    shapes = {
        "tok_emb": (m.vocab_size, m.d_model),
        "ln1": (m.n_layers, m.d_model),
        "wq": (m.n_layers, m.d_model, m.n_heads * dh),
        "wk": (m.n_layers, m.d_model, m.n_kv_heads * dh),
        "wv": (m.n_layers, m.d_model, m.n_kv_heads * dh),
        "wo": (m.n_layers, m.n_heads * dh, m.d_model),
        "ln2": (m.n_layers, m.d_model),
        "w_gate": (m.n_layers, m.d_model, m.d_ff),
        "w_up": (m.n_layers, m.d_model, m.d_ff),
        "w_down": (m.n_layers, m.d_ff, m.d_model),
        "ln_f": (m.d_model,),
        "lm_head": (m.d_model, m.vocab_size),
    }
    return {name: shapes[name] for name in PARAM_NAMES}


def param_count(cfg):
    return sum(int(jnp.prod(jnp.asarray(s))) for s in param_shapes(cfg).values())


def init_params(cfg, seed):
    """Initialise all parameters from an int32 seed (compiled to init.hlo)."""
    shapes = param_shapes(cfg)
    key = jax.random.PRNGKey(seed)
    out = []
    scale_out = 0.02 / jnp.sqrt(2.0 * cfg.model.n_layers)
    for i, name in enumerate(PARAM_NAMES):
        shape = shapes[name]
        if name in ("ln1", "ln2", "ln_f"):
            out.append(jnp.ones(shape, jnp.float32))
            continue
        k = jax.random.fold_in(key, i)
        std = scale_out if name in ("wo", "w_down") else 0.02
        out.append(jax.random.normal(k, shape, jnp.float32) * std)
    return tuple(out)


def params_dict(flat):
    """Flat tuple (PARAM_NAMES order) -> dict."""
    return dict(zip(PARAM_NAMES, flat))


# ---------------------------------------------------------------------------
# Transformer forward


def rmsnorm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, theta):
    """Rotary embedding, GPT-NeoX half-split convention.

    x: [..., S, H, Dh]; pos: broadcastable to [..., S].
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = pos[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(h, wg, wu, wd):
    return (nn.silu(h @ wg) * (h @ wu)) @ wd


def forward(cfg, p, tokens, pos, mask=None, spa_info=None, attn_impl="jnp"):
    """Transformer forward.

    Args:
      p: params dict; tokens/pos: [B, S] int32.
      mask: [B or 1, 1, S, S] bool (jnp attention path).
      spa_info: (seg [S], pos [S], prompt_len scalar) for the pallas path
        (requires B == 1; the packed SPA layout).
      attn_impl: "jnp" (dense-mask oracle, default for AOT) or "pallas".
    Returns: logits [B, S, V] float32.
    """
    m = cfg.model
    b, s = tokens.shape
    dh = m.head_dim
    x = p["tok_emb"][tokens]  # [B, S, D]

    layer_stack = tuple(p[name] for name in LAYER_PARAMS)

    def layer(x, lp):
        ln1, wq, wk, wv, wo, ln2, wg, wu, wd = lp
        h = rmsnorm(x, ln1, m.rmsnorm_eps)
        q = (h @ wq).reshape(b, s, m.n_heads, dh)
        k = (h @ wk).reshape(b, s, m.n_kv_heads, dh)
        v = (h @ wv).reshape(b, s, m.n_kv_heads, dh)
        q = rope(q, pos, m.rope_theta).transpose(0, 2, 1, 3)  # [B, Hq, S, Dh]
        k = rope(k, pos, m.rope_theta).transpose(0, 2, 1, 3)  # [B, Hk, S, Dh]
        v = v.transpose(0, 2, 1, 3)
        if attn_impl == "pallas":
            assert spa_info is not None, "pallas path needs spa_info"
            seg1, pos1, plen = spa_info
            att = spa_attention(q, k, v, seg1, pos1, plen)
        else:
            att = kref.attention_ref(q, k, v, mask)
        att = att.transpose(0, 2, 1, 3).reshape(b, s, m.n_heads * dh)
        x = x + att @ wo
        x = x + swiglu(rmsnorm(x, ln2, m.rmsnorm_eps), wg, wu, wd)
        return x, None

    x, _ = jax.lax.scan(layer, x, layer_stack)
    x = rmsnorm(x, p["ln_f"], m.rmsnorm_eps)
    return x @ p["lm_head"]


# ---------------------------------------------------------------------------
# GRPO tri-model train step


def _label_logprobs(logits, labels, impl="jnp"):
    """[B, S, V], [B, S] -> [B, S] log p(label)."""
    if impl == "pallas":
        b, s, v = logits.shape
        return logprob_gather(logits.reshape(b * s, v), labels.reshape(b * s)).reshape(b, s)
    return kref.logprob_gather_ref(logits, labels)


def grpo_objective(cfg, lp_pol, lp_old, lp_ref, adv, weight, logits_pol):
    """Per-token clipped-surrogate + k3-KL GRPO loss (paper Eq. 1 terms).

    All inputs [B, S]; weight encodes 1/(n_samples * |o_k|) on response-token
    label positions and 0 elsewhere (sums to 1 over the micro-batch).
    Returns (loss, metrics dict of scalars).
    """
    t = cfg.train
    ratio = jnp.exp(lp_pol - lp_old)
    clipped = jnp.clip(ratio, 1.0 - t.clip_eps_low, 1.0 + t.clip_eps_high)
    surr = jnp.minimum(ratio * adv, clipped * adv)
    log_rr = lp_ref - lp_pol
    kl = jnp.exp(log_rr) - log_rr - 1.0  # k3 estimator, >= 0
    obj = surr - t.kl_beta * kl
    loss = -jnp.sum(weight * obj)

    probs = nn.softmax(logits_pol, axis=-1)
    ent_t = -jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1)
    is_clipped = (ratio < 1.0 - t.clip_eps_low) | (ratio > 1.0 + t.clip_eps_high)
    w_sum = jnp.sum(weight) + 1e-9
    metrics = {
        "kl": jnp.sum(weight * kl) / w_sum,
        "clip_frac": jnp.sum(weight * is_clipped.astype(jnp.float32)) / w_sum,
        "entropy": jnp.sum(weight * ent_t) / w_sum,
        "ratio_mean": jnp.sum(weight * ratio) / w_sum,
    }
    return loss, metrics


# Names/order of the scalar metrics appended to train-step outputs.
TRAIN_METRICS = ("loss", "kl", "clip_frac", "entropy", "ratio_mean")


def make_train_step(cfg, spa, attn_impl="jnp", lp_impl="jnp"):
    """Build the tri-model train step.

    Signature (flat, matching manifest.json):
      policy params (12), old params (12), ref params (12),
      tokens [m,S] i32, labels [m,S] i32, pos [m,S] i32, seg [m,S] i32,
      adv [m,S] f32, weight [m,S] f32, prompt_len () i32
    Returns: grads (12) + 5 scalar metrics.

    ``spa`` selects the packed shared-prompt layout ([1, pack_len], mask from
    seg/pos/prompt_len) versus the standard causal layout ([micro_bs,
    seq_len]). Both read the same input names; the standard layout ignores
    prompt_len and uses seg only to mask padding.
    """
    n = len(PARAM_NAMES)

    def step(*args):
        pol = params_dict(args[0:n])
        old = params_dict(args[n : 2 * n])
        ref_p = params_dict(args[2 * n : 3 * n])
        tokens, labels, pos, seg, adv, weight, prompt_len = args[3 * n :]

        if spa:
            seg1 = seg[0]
            pos1 = pos[0]
            mask = kref.spa_mask(seg1, pos1, prompt_len)[None, None]
            spa_info = (seg1, pos1, prompt_len)
        else:
            s = tokens.shape[1]
            # causal + padding keys masked (pad tokens have seg -1)
            valid = (seg >= 0)[:, None, None, :]  # [m,1,1,S]
            mask = (kref.causal_mask(s)[None, None] & valid) | jnp.eye(s, dtype=bool)[None, None]
            spa_info = None
            # prompt_len is unused in the standard layout; anchor it so the
            # lowered signature matches the SPA variant (jax would DCE the
            # parameter otherwise and the rust runtime's arity check breaks).
            tokens = tokens + 0 * prompt_len

        def loss_fn(pol_params):
            logits = forward(cfg, pol_params, tokens, pos, mask, spa_info, attn_impl)
            lp_pol = _label_logprobs(logits, labels, lp_impl)
            logits_old = forward(cfg, old, tokens, pos, mask, spa_info, attn_impl)
            logits_ref = forward(cfg, ref_p, tokens, pos, mask, spa_info, attn_impl)
            lp_old = jax.lax.stop_gradient(_label_logprobs(logits_old, labels, lp_impl))
            lp_ref = jax.lax.stop_gradient(_label_logprobs(logits_ref, labels, lp_impl))
            loss, metrics = grpo_objective(cfg, lp_pol, lp_old, lp_ref, adv, weight, logits)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(pol)
        flat_grads = tuple(grads[name] for name in PARAM_NAMES)
        return flat_grads + (loss, metrics["kl"], metrics["clip_frac"], metrics["entropy"], metrics["ratio_mean"])

    return step


def train_step_example_args(cfg, spa):
    """ShapeDtypeStructs matching make_train_step's signature."""
    if spa:
        rows, s = 1, cfg.train.spa_pack_len
    else:
        rows, s = cfg.train.micro_bs, cfg.train.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[name], f32) for name in PARAM_NAMES]
    batch = [
        jax.ShapeDtypeStruct((rows, s), i32),  # tokens
        jax.ShapeDtypeStruct((rows, s), i32),  # labels
        jax.ShapeDtypeStruct((rows, s), i32),  # pos
        jax.ShapeDtypeStruct((rows, s), i32),  # seg
        jax.ShapeDtypeStruct((rows, s), f32),  # adv
        jax.ShapeDtypeStruct((rows, s), f32),  # weight
        jax.ShapeDtypeStruct((), i32),  # prompt_len
    ]
    return params * 3 + batch


# ---------------------------------------------------------------------------
# SFT warmup step (supervised CE on response tokens)


def make_sft_step(cfg, attn_impl="jnp"):
    n = len(PARAM_NAMES)

    def step(*args):
        pol = params_dict(args[0:n])
        tokens, labels, pos, seg, weight = args[n:]
        s = tokens.shape[1]
        valid = (seg >= 0)[:, None, None, :]
        mask = (kref.causal_mask(s)[None, None] & valid) | jnp.eye(s, dtype=bool)[None, None]

        def loss_fn(p):
            logits = forward(cfg, p, tokens, pos, mask, None, attn_impl)
            lp = _label_logprobs(logits, labels)
            return -jnp.sum(weight * lp)

        loss, grads = jax.value_and_grad(loss_fn)(pol)
        return tuple(grads[name] for name in PARAM_NAMES) + (loss,)

    return step


def sft_step_example_args(cfg):
    rows, s = cfg.train.micro_bs, cfg.train.seq_len
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[name], jnp.float32) for name in PARAM_NAMES]
    batch = [
        jax.ShapeDtypeStruct((rows, s), jnp.int32),
        jax.ShapeDtypeStruct((rows, s), jnp.int32),
        jax.ShapeDtypeStruct((rows, s), jnp.int32),
        jax.ShapeDtypeStruct((rows, s), jnp.int32),
        jax.ShapeDtypeStruct((rows, s), jnp.float32),
    ]
    return params + batch


# ---------------------------------------------------------------------------
# Logprob evaluator (tests: engine logprobs == tri-model old logprobs)


def make_logprob_eval(cfg, attn_impl="jnp"):
    n = len(PARAM_NAMES)

    def step(*args):
        p = params_dict(args[0:n])
        tokens, labels, pos, seg = args[n:]
        s = tokens.shape[1]
        valid = (seg >= 0)[:, None, None, :]
        mask = (kref.causal_mask(s)[None, None] & valid) | jnp.eye(s, dtype=bool)[None, None]
        logits = forward(cfg, p, tokens, pos, mask, None, attn_impl)
        return (_label_logprobs(logits, labels),)

    return step


def logprob_eval_example_args(cfg):
    rows, s = cfg.train.micro_bs, cfg.train.seq_len
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[name], jnp.float32) for name in PARAM_NAMES]
    batch = [jax.ShapeDtypeStruct((rows, s), jnp.int32) for _ in range(4)]
    return params + batch


# ---------------------------------------------------------------------------
# Inference engine: prefill + decode chunk over a slot-paged KV cache
#
# Cache layout: [L, B, 2, Sc, Hk, Dh] float32 — per layer, per slot, (k, v),
# cache position, kv head, head dim. One device-resident buffer.


def kv_cache_shape(cfg):
    m, e = cfg.model, cfg.engine
    return (m.n_layers, e.n_slots, 2, e.cache_len, m.n_kv_heads, m.head_dim)


def make_prefill(cfg, attn_impl="jnp"):
    """Prefill one slot: run the prompt, write its K/V into the cache.

    Signature: params (12), kv [cache], slot () i32, tokens [P] i32,
    length () i32 -> (kv', last_logits [V]).
    """
    m, e = cfg.model, cfg.engine
    n = len(PARAM_NAMES)
    dh = m.head_dim
    p_max = e.prompt_max

    def step(*args):
        p = params_dict(args[0:n])
        kv, slot, tokens, length = args[n:]
        tokens2 = tokens[None]  # [1, P]
        pos = jnp.arange(p_max, dtype=jnp.int32)[None]
        i = jnp.arange(p_max)[:, None]
        j = jnp.arange(p_max)[None, :]
        mask = ((j <= i) & (j < length) | (i == j))[None, None]

        x = p["tok_emb"][tokens2]
        layer_stack = tuple(p[name] for name in LAYER_PARAMS)
        kv_in = jnp.moveaxis(kv, 0, 0)  # [L, B, 2, Sc, Hk, Dh]

        def layer(x, lp_kv):
            lp, kv_l = lp_kv  # kv_l: [B, 2, Sc, Hk, Dh]
            ln1, wq, wk, wv, wo, ln2, wg, wu, wd = lp
            h = rmsnorm(x, ln1, m.rmsnorm_eps)
            q = (h @ wq).reshape(1, p_max, m.n_heads, dh)
            k = (h @ wk).reshape(1, p_max, m.n_kv_heads, dh)
            v = (h @ wv).reshape(1, p_max, m.n_kv_heads, dh)
            q = rope(q, pos, m.rope_theta).transpose(0, 2, 1, 3)
            k_r = rope(k, pos, m.rope_theta)  # [1, P, Hk, Dh]
            att = kref.attention_ref(q, k_r.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), mask)
            att = att.transpose(0, 2, 1, 3).reshape(1, p_max, m.n_heads * dh)
            x = x + att @ wo
            x = x + swiglu(rmsnorm(x, ln2, m.rmsnorm_eps), wg, wu, wd)
            # Write prompt K/V into this slot's cache rows [0, P).
            kv_pair = jnp.stack([k_r[0], v[0]], axis=0)  # [2, P, Hk, Dh]
            kv_l = jax.lax.dynamic_update_slice(kv_l, kv_pair[None], (slot, 0, 0, 0, 0))
            return x, kv_l

        x, kv_out = jax.lax.scan(layer, x, (layer_stack, kv_in))
        x = rmsnorm(x, p["ln_f"], m.rmsnorm_eps)
        last = jax.lax.dynamic_slice(x, (0, length - 1, 0), (1, 1, m.d_model))[0, 0]
        logits = last @ p["lm_head"]
        return kv_out, logits

    return step


def prefill_example_args(cfg):
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[name], jnp.float32) for name in PARAM_NAMES]
    return params + [
        jax.ShapeDtypeStruct(kv_cache_shape(cfg), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((cfg.engine.prompt_max,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]


def make_prefill_chunk(cfg):
    """Prefill one fixed-size chunk of a slot's prompt, resuming from cached
    rows.

    The engine's partial-prefix reuse: rows ``[0, start)`` of the slot's KV
    cache already hold the prompt prefix (restored from the shared-prefix
    cache, or written by earlier chunks); this artifact ingests the next
    ``length <= cache_block`` prompt tokens at cache positions
    ``[start, start + length)``, attending to the whole resident prefix, and
    returns the logits at position ``start + length - 1`` (the prompt's last
    position on the final chunk, from which the engine samples the first
    response token).

    Signature: params (12), kv [cache], slot () i32, tokens [Cb] i32,
    start () i32, length () i32 -> (kv', last_logits [V]).

    Unlike the monolithic ``prefill`` (which writes its full padded token
    window), only the ``length`` valid rows are written — padded tail
    positions scatter out-of-bounds and are dropped, so a chunk near the end
    of the prompt can never clobber response rows.
    """
    m, e = cfg.model, cfg.engine
    n = len(PARAM_NAMES)
    dh = m.head_dim
    cb = e.cache_block
    sc = e.cache_len

    def step(*args):
        p = params_dict(args[0:n])
        kv, slot, tokens, start, length = args[n:]
        tokens2 = tokens[None]  # [1, Cb]
        pos = start + jnp.arange(cb, dtype=jnp.int32)  # [Cb] cache positions
        i = jnp.arange(cb)[:, None]  # query index within the chunk
        j = jnp.arange(sc)[None, :]  # key cache position
        qpos = start + i
        # Causal over the resident prefix + this chunk's valid tokens; padded
        # queries (i >= length) keep their own position so softmax stays
        # finite (their outputs are never read).
        mask = (((j <= qpos) & (j < start + length)) | (j == qpos))[None, None]

        x = p["tok_emb"][tokens2]  # [1, Cb, D]
        layer_stack = tuple(p[name] for name in LAYER_PARAMS)
        # Valid rows scatter at [start, start + length); the padded tail is
        # redirected out of bounds and dropped.
        rows_idx = jnp.where(jnp.arange(cb) < length, pos, sc)

        def layer(x, lp_kv):
            lp, kv_l = lp_kv  # kv_l: [B, 2, Sc, Hk, Dh]
            ln1, wq, wk, wv, wo, ln2, wg, wu, wd = lp
            h = rmsnorm(x, ln1, m.rmsnorm_eps)
            q = (h @ wq).reshape(1, cb, m.n_heads, dh)
            k = (h @ wk).reshape(1, cb, m.n_kv_heads, dh)
            v = (h @ wv).reshape(1, cb, m.n_kv_heads, dh)
            q = rope(q, pos[None], m.rope_theta).transpose(0, 2, 1, 3)  # [1,Hq,Cb,Dh]
            k_r = rope(k, pos[None], m.rope_theta)  # [1, Cb, Hk, Dh]
            pair = jnp.stack([k_r[0], v[0]], axis=1)  # [Cb, 2, Hk, Dh]
            kv_l = kv_l.at[slot, :, rows_idx].set(pair, mode="drop")
            # Attend over the slot's full cache row range (masked).
            cache = jax.lax.dynamic_slice(
                kv_l, (slot, 0, 0, 0, 0), (1, 2, sc, m.n_kv_heads, dh)
            )
            k_all = cache[:, 0].transpose(0, 2, 1, 3)  # [1, Hk, Sc, Dh]
            v_all = cache[:, 1].transpose(0, 2, 1, 3)
            att = kref.attention_ref(q, k_all, v_all, mask)  # [1, Hq, Cb, Dh]
            att = att.transpose(0, 2, 1, 3).reshape(1, cb, m.n_heads * dh)
            x = x + att @ wo
            x = x + swiglu(rmsnorm(x, ln2, m.rmsnorm_eps), wg, wu, wd)
            return x, kv_l

        x, kv_out = jax.lax.scan(layer, x, (layer_stack, kv))
        x = rmsnorm(x, p["ln_f"], m.rmsnorm_eps)
        last = jax.lax.dynamic_slice(x, (0, length - 1, 0), (1, 1, m.d_model))[0, 0]
        logits = last @ p["lm_head"]
        return kv_out, logits

    return step


def prefill_chunk_example_args(cfg):
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[name], jnp.float32) for name in PARAM_NAMES]
    return params + [
        jax.ShapeDtypeStruct(kv_cache_shape(cfg), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((cfg.engine.cache_block,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]


def truncate_logits(logits, temperature, top_p, top_k):
    """Temperature-scale [B, V] logits and apply the top-k / top-p masks.

    Top-k tie rule (mirrored by the host sampler in
    `rust/src/engine/sampler.rs`): every token whose scaled logit is >= the
    k-th largest value is kept, so ties at the cutoff widen the support past
    `top_k` — ties are never broken by token index. NaN logits fail the
    `>= kth` comparison and are masked out.

    Returns masked scaled logits (dropped tokens at -1e30).
    """
    v = logits.shape[-1]
    scaled = logits / jnp.maximum(temperature, 1e-6)
    # top-k (static config; 0 disables)
    if top_k and top_k > 0 and top_k < v:
        kth = jnp.sort(scaled, axis=-1)[:, v - top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -1e30)
    # top-p nucleus
    sort_idx = jnp.argsort(-scaled, axis=-1)
    sorted_logits = jnp.take_along_axis(scaled, sort_idx, axis=-1)
    probs = nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1) - probs
    keep_sorted = cum < top_p  # always keeps the top token
    keep = jnp.zeros_like(keep_sorted).at[
        jnp.arange(logits.shape[0])[:, None], sort_idx
    ].set(keep_sorted)
    return jnp.where(keep, scaled, -1e30)


def sample_token(logits, key, temperature, top_p, top_k):
    """Temperature / top-p / top-k sampling (greedy when temperature ~ 0).

    logits: [B, V], one shared key for the whole batch; returns (tokens [B]
    i32, logprob [B] under the sampling distribution). See `truncate_logits`
    for the top-k tie rule shared with the host sampler.
    """
    masked = truncate_logits(logits, temperature, top_p, top_k)
    sampled = jax.random.categorical(key, masked, axis=-1)
    greedy = jnp.argmax(logits, axis=-1)
    tok = jnp.where(temperature > 1e-6, sampled, greedy).astype(jnp.int32)
    lp = kref.logprob_gather_ref(masked, tok)
    return tok, lp


def sample_token_per_slot(logits, keys, temperature, top_p, top_k):
    """Like `sample_token` but with one PRNG key per row (keys: [B, 2]).

    Each slot draws from its own request's stream, so a slot's sampled token
    is a pure function of that request's (seed, step) — independent of which
    batch-mates share the decode chunk. This is what makes rollouts
    bit-identical across fleet sizes and placements at temperature > 0.
    """
    masked = truncate_logits(logits, temperature, top_p, top_k)
    sampled = jax.vmap(jax.random.categorical)(keys, masked)
    greedy = jnp.argmax(logits, axis=-1)
    tok = jnp.where(temperature > 1e-6, sampled, greedy).astype(jnp.int32)
    lp = kref.logprob_gather_ref(masked, tok)
    return tok, lp


def make_decode(cfg):
    """Decode a chunk of C tokens for all slots.

    Signature: params (12), kv [cache], tokens [B] i32 (each slot's current
    last token), pos [B] i32 (cache index where that token's K/V goes),
    active [B] i32, seeds [B] i32 (per-slot, each derived on the host from
    the occupying request's own stream at its current decode step),
    temperature () f32, top_p () f32
      -> (kv', out_tokens [B, C] i32, out_logprobs [B, C] f32,
          new_pos [B] i32, new_active [B] i32).

    Per step: write the current token's K/V at pos, attend j <= pos, sample
    the next token. A slot that samples EOS (or hits cache capacity) goes
    inactive within the chunk: it emits PAD, stops advancing and stops
    writing K/V. The rust engine retires it and admits a new sequence.
    """
    m, e = cfg.model, cfg.engine
    n = len(PARAM_NAMES)
    dh = m.head_dim
    b = e.n_slots
    sc = e.cache_len
    c = e.decode_chunk
    n_rep = m.n_heads // m.n_kv_heads

    def step(*args):
        p = params_dict(args[0:n])
        kv0, tok0, pos0, active0, seeds, temperature, top_p = args[n:]
        # One base key per slot: the chunk-local step offset is folded in
        # below, so token (base_step + step_i) of a request depends only on
        # its own seed — never on batch composition.
        keys = jax.vmap(jax.random.PRNGKey)(seeds)
        layer_stack = tuple(p[name] for name in LAYER_PARAMS)

        def one_step(carry, step_i):
            kv, tok, pos, active = carry
            x = p["tok_emb"][tok]  # [B, D]

            def layer(x, lp_kv):
                lp, kv_l = lp_kv  # kv_l: [B, 2, Sc, Hk, Dh]
                ln1, wq, wk, wv, wo, ln2, wg, wu, wd = lp
                h = rmsnorm(x, ln1, m.rmsnorm_eps)
                q = (h @ wq).reshape(b, m.n_heads, dh)
                k_new = (h @ wk).reshape(b, m.n_kv_heads, dh)
                v_new = (h @ wv).reshape(b, m.n_kv_heads, dh)
                # rope at per-slot position
                q = rope(q[:, None], pos[:, None], m.rope_theta)[:, 0]
                k_new = rope(k_new[:, None], pos[:, None], m.rope_theta)[:, 0]

                def upd(cache_s, kn, vn, pp, act):
                    # cache_s: [2, Sc, Hk, Dh]
                    pair = jnp.stack([kn, vn], 0)[:, None]  # [2,1,Hk,Dh]
                    new = jax.lax.dynamic_update_slice(cache_s, pair, (0, pp, 0, 0))
                    return jnp.where(act > 0, new, cache_s)

                kv_l = jax.vmap(upd)(kv_l, k_new, v_new, pos, active)
                k_all = kv_l[:, 0]  # [B, Sc, Hk, Dh]
                v_all = kv_l[:, 1]
                # GQA expand and attend j <= pos
                k_all = jnp.repeat(k_all, n_rep, axis=2)  # [B, Sc, Hq, Dh]
                v_all = jnp.repeat(v_all, n_rep, axis=2)
                scores = jnp.einsum("bhd,bshd->bhs", q, k_all) / jnp.sqrt(float(dh))
                jmask = jnp.arange(sc)[None, None, :] <= pos[:, None, None]
                scores = jnp.where(jmask, scores, -1e30)
                att = jnp.einsum("bhs,bshd->bhd", nn.softmax(scores, axis=-1), v_all)
                x = x + att.reshape(b, m.n_heads * dh) @ wo
                x = x + swiglu(rmsnorm(x, ln2, m.rmsnorm_eps), wg, wu, wd)
                return x, kv_l

            x, kv = jax.lax.scan(layer, x, (layer_stack, kv))
            x = rmsnorm(x, p["ln_f"], m.rmsnorm_eps)
            logits = x @ p["lm_head"]  # [B, V]
            k_step = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, step_i)
            nxt, lp = sample_token_per_slot(logits, k_step, temperature, top_p, e.top_k)
            is_active = active > 0
            tok_out = jnp.where(is_active, nxt, PAD_ID).astype(jnp.int32)
            lp_out = jnp.where(is_active, lp, 0.0)
            new_pos = pos + is_active.astype(jnp.int32)
            hit_eos = tok_out == EOS_ID
            full = new_pos >= sc
            new_active = (is_active & ~hit_eos & ~full).astype(jnp.int32)
            return (kv, tok_out, new_pos, new_active), (tok_out, lp_out)

        (kv, _, pos_f, act_f), (toks, lps) = jax.lax.scan(
            one_step, (kv0, tok0, pos0, active0), jnp.arange(c)
        )
        return kv, toks.T, lps.T, pos_f, act_f  # [B, C]

    return step


def decode_example_args(cfg):
    shapes = param_shapes(cfg)
    b = cfg.engine.n_slots
    params = [jax.ShapeDtypeStruct(shapes[name], jnp.float32) for name in PARAM_NAMES]
    return params + [
        jax.ShapeDtypeStruct(kv_cache_shape(cfg), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),  # per-slot seeds
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ]


# ---------------------------------------------------------------------------
# AdamW with global-norm clipping


def make_adam(cfg):
    """AdamW step (paper Table 7: Adam, wd 0.01, grad-norm clip 1.0).

    Signature: params (12), grads (12), m (12), v (12), step () i32
      -> params' (12) + m' (12) + v' (12) + (grad_norm,).
    Weight decay is decoupled and skipped for the RMSNorm gains.
    """
    t = cfg.train
    n = len(PARAM_NAMES)
    no_decay = {"ln1", "ln2", "ln_f"}

    def step(*args):
        params = args[0:n]
        grads = args[n : 2 * n]
        ms = args[2 * n : 3 * n]
        vs = args[3 * n : 4 * n]
        step_i = args[4 * n]

        gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads)
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, t.grad_clip / (gnorm + 1e-12))

        tf = step_i.astype(jnp.float32) + 1.0
        bc1 = 1.0 - t.beta1**tf
        bc2 = 1.0 - t.beta2**tf

        new_p, new_m, new_v = [], [], []
        for name, p, g, m_, v_ in zip(PARAM_NAMES, params, grads, ms, vs):
            g = g * scale
            m2 = t.beta1 * m_ + (1.0 - t.beta1) * g
            v2 = t.beta2 * v_ + (1.0 - t.beta2) * (g * g)
            mhat = m2 / bc1
            vhat = v2 / bc2
            upd = mhat / (jnp.sqrt(vhat) + t.adam_eps)
            if name not in no_decay:
                upd = upd + t.weight_decay * p
            new_p.append(p - t.lr * upd)
            new_m.append(m2)
            new_v.append(v2)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (gnorm,)

    return step


def adam_example_args(cfg):
    shapes = param_shapes(cfg)
    ts = [jax.ShapeDtypeStruct(shapes[name], jnp.float32) for name in PARAM_NAMES]
    return ts * 4 + [jax.ShapeDtypeStruct((), jnp.int32)]


# ---------------------------------------------------------------------------
# Reference (pure-jax) GRPO loss for pytest oracles


def reference_grpo_loss(cfg, params, batch, attn_impl="jnp"):
    """Direct (non-AOT) tri-model loss used by tests; params is a dict of
    (policy, old, ref) param dicts; batch a dict of arrays."""
    step = make_train_step(cfg, spa=batch.get("spa", False), attn_impl=attn_impl)
    flat = (
        tuple(params["policy"][nm] for nm in PARAM_NAMES)
        + tuple(params["old"][nm] for nm in PARAM_NAMES)
        + tuple(params["ref"][nm] for nm in PARAM_NAMES)
        + (
            batch["tokens"],
            batch["labels"],
            batch["pos"],
            batch["seg"],
            batch["adv"],
            batch["weight"],
            batch["prompt_len"],
        )
    )
    out = step(*flat)
    n = len(PARAM_NAMES)
    grads = dict(zip(PARAM_NAMES, out[0:n]))
    metrics = dict(zip(TRAIN_METRICS, out[n:]))
    return grads, metrics
