"""L1 correctness: the Pallas SPA attention kernel vs the pure-jnp oracle.

The CORE kernel signal: hypothesis sweeps shapes/dtypes/segment layouts and
asserts allclose against ref.attention_ref(ref.spa_mask(...)).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spa_attention import (
    causal_attention,
    mxu_tile_utilization,
    spa_attention,
    vmem_estimate_bytes,
)


def make_spa_layout(rng, s, lp, seg_lens):
    """Build seg/pos arrays for a packed layout: prompt of lp, segments of
    seg_lens (each starting at rope position lp-1), padding to s."""
    seg = np.full((s,), -1, np.int32)
    pos = np.zeros((s,), np.int32)
    seg[:lp] = 0
    pos[:lp] = np.arange(lp)
    cursor = lp
    for k, ln in enumerate(seg_lens, start=1):
        seg[cursor : cursor + ln] = k
        pos[cursor : cursor + ln] = lp - 1 + np.arange(ln)
        cursor += ln
    assert cursor <= s
    return jnp.asarray(seg), jnp.asarray(pos)


def rand_qkv(key, b, hq, hk, s, dh, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, s, dh), dtype)
    k = jax.random.normal(kk, (b, hk, s, dh), dtype)
    v = jax.random.normal(kv, (b, hk, s, dh), dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hk,s,dh", [(1, 2, 1, 32, 8), (2, 4, 2, 64, 16), (1, 4, 4, 32, 4)])
def test_kernel_matches_ref_fixed_shapes(b, hq, hk, s, dh):
    key = jax.random.PRNGKey(0)
    q, k, v = rand_qkv(key, b, hq, hk, s, dh)
    lp = s // 4
    seg, pos = make_spa_layout(None, s, lp, [s // 4, s // 4])
    plen = jnp.asarray(lp, jnp.int32)
    out = spa_attention(q, k, v, seg, pos, plen, block_q=16, block_k=16)
    mask = ref.spa_mask(seg, pos, plen)[None, None]
    expect = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    hk=st.sampled_from([1, 2]),
    rep=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8, 16]),
    nblocks=st.integers(2, 4),
    block=st.sampled_from([8, 16]),
)
def test_kernel_matches_ref_hypothesis(seed, hk, rep, dh, nblocks, block):
    s = nblocks * block
    rng = np.random.default_rng(seed)
    lp = int(rng.integers(2, max(3, s // 2)))
    # random segment lengths that fit
    seg_lens = []
    room = s - lp
    while room > 0 and len(seg_lens) < 4 and rng.random() < 0.8:
        ln = int(rng.integers(1, room + 1))
        seg_lens.append(ln)
        room -= ln
    key = jax.random.PRNGKey(seed)
    q, k, v = rand_qkv(key, 1, hk * rep, hk, s, dh)
    seg, pos = make_spa_layout(rng, s, lp, seg_lens)
    plen = jnp.asarray(lp, jnp.int32)
    out = spa_attention(q, k, v, seg, pos, plen, block_q=block, block_k=block)
    mask = ref.spa_mask(seg, pos, plen)[None, None]
    expect = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=3e-5, atol=3e-5)


def test_causal_wrapper_matches_causal_ref():
    key = jax.random.PRNGKey(7)
    q, k, v = rand_qkv(key, 2, 4, 2, 32, 8)
    out = causal_attention(q, k, v, block_q=16, block_k=16)
    mask = ref.causal_mask(32)[None, None]
    expect = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_no_cross_response_leakage():
    """Perturbing response 2's tokens must not change response 1's outputs."""
    key = jax.random.PRNGKey(3)
    s, lp = 32, 8
    seg, pos = make_spa_layout(None, s, lp, [8, 8])
    plen = jnp.asarray(lp, jnp.int32)
    q, k, v = rand_qkv(key, 1, 2, 1, s, 8)
    out1 = spa_attention(q, k, v, seg, pos, plen, block_q=8, block_k=8)
    # perturb k/v/q rows of segment 2 (indices 16..24)
    noise = jax.random.normal(jax.random.PRNGKey(9), (1, 1, 8, 8)) * 10
    k2 = k.at[:, :, 16:24].add(noise)
    v2 = v.at[:, :, 16:24].add(noise)
    out2 = spa_attention(q, k2, v2, seg, pos, plen, block_q=8, block_k=8)
    # segment 1 (rows 8..16) and prompt rows unchanged
    np.testing.assert_allclose(
        np.asarray(out1[:, :, :16]), np.asarray(out2[:, :, :16]), rtol=1e-6, atol=1e-6
    )
    # segment 2 rows do change
    assert not np.allclose(np.asarray(out1[:, :, 16:24]), np.asarray(out2[:, :, 16:24]))


def test_original_last_prompt_token_key_excluded_for_responses():
    """Responses must attend the duplicated prompt-last token (inside their own
    segment), not the original at index lp-1 — perturbing the original's K/V
    must leave response outputs unchanged."""
    key = jax.random.PRNGKey(4)
    s, lp = 32, 8
    seg, pos = make_spa_layout(None, s, lp, [8, 8])
    plen = jnp.asarray(lp, jnp.int32)
    q, k, v = rand_qkv(key, 1, 2, 1, s, 8)
    out1 = spa_attention(q, k, v, seg, pos, plen, block_q=8, block_k=8)
    k2 = k.at[:, :, lp - 1].add(5.0)
    v2 = v.at[:, :, lp - 1].add(5.0)
    out2 = spa_attention(q, k2, v2, seg, pos, plen, block_q=8, block_k=8)
    np.testing.assert_allclose(
        np.asarray(out1[:, :, lp:]), np.asarray(out2[:, :, lp:]), rtol=1e-6, atol=1e-6
    )


def test_mask_reference_properties():
    """Sanity of the mask itself (unit-level, no kernel)."""
    s, lp = 16, 6
    seg, pos = make_spa_layout(None, s, lp, [4, 3])
    m = np.asarray(ref.spa_mask(seg, pos, jnp.asarray(lp, jnp.int32)))
    # prompt is standard causal
    for i in range(lp):
        for j in range(s):
            assert m[i, j] == (j <= i and seg[j] == 0)
    # response tokens never attend other responses
    assert not m[lp + 1, lp + 4]  # seg1 q, seg2 key region
    assert not m[lp + 4, lp]  # seg2 q, seg1 key
    # response tokens attend prompt keys with pos < lp-1 only
    assert m[lp, 0] and m[lp, lp - 2]
    assert not m[lp, lp - 1]
    # padding attends itself only
    pad_row = lp + 7
    assert seg[pad_row] == -1
    assert m[pad_row, pad_row]
    assert m[pad_row].sum() == 1


def test_vmem_and_mxu_estimators():
    vb = vmem_estimate_bytes(s=2048, dh=128, block_q=128, block_k=128)
    assert vb < 16 * 1024 * 1024, "VMEM estimate must fit a TPU core's ~16MB"
    assert mxu_tile_utilization(128, 128, 128) == 1.0
    assert mxu_tile_utilization(64, 128, 128) == 0.5
