"""L2 correctness: transformer forward, tri-model GRPO step, SPA gradient
equivalence (the paper's central ∇L_shared = Σ_k ∇L_k claim), Eq. 1
micro-batch equivalence, engine prefill/decode vs the training forward,
AdamW, and the in-graph sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import tiny_test_config
from compile.kernels import ref as kref
from .helpers import build_spa, build_standard, random_group

CFG = tiny_test_config()
N = len(model.PARAM_NAMES)


def get_params(seed=0):
    return model.init_params(CFG, seed)


def as_dict(flat):
    return model.params_dict(flat)


def run_train_step(cfg, spa, pol, old, refp, batch):
    step = model.make_train_step(cfg, spa=spa)
    args = (
        tuple(pol)
        + tuple(old)
        + tuple(refp)
        + (
            jnp.asarray(batch["tokens"]),
            jnp.asarray(batch["labels"]),
            jnp.asarray(batch["pos"]),
            jnp.asarray(batch["seg"]),
            jnp.asarray(batch["adv"]),
            jnp.asarray(batch["weight"]),
            jnp.asarray(batch["prompt_len"]),
        )
    )
    out = jax.jit(step)(*args)
    grads = out[:N]
    metrics = dict(zip(model.TRAIN_METRICS, [float(x) for x in out[N:]]))
    return grads, metrics


class TestForward:
    def test_shapes(self):
        p = as_dict(get_params())
        tokens = jnp.ones((2, 8), jnp.int32)
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
        mask = kref.causal_mask(8)[None, None]
        logits = model.forward(CFG, p, tokens, pos, mask)
        assert logits.shape == (2, 8, CFG.model.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self):
        """Changing a later token must not affect earlier logits."""
        p = as_dict(get_params())
        key = jax.random.PRNGKey(0)
        tokens = jax.random.randint(key, (1, 8), 3, CFG.model.vocab_size)
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        mask = kref.causal_mask(8)[None, None]
        a = model.forward(CFG, p, tokens, pos, mask)
        tokens2 = tokens.at[0, 6].set(5)
        b = model.forward(CFG, p, tokens2, pos, mask)
        np.testing.assert_allclose(np.asarray(a[0, :6]), np.asarray(b[0, :6]), rtol=1e-5, atol=1e-6)
        assert not np.allclose(np.asarray(a[0, 6:]), np.asarray(b[0, 6:]))

    def test_param_count_matches_rust_formula(self):
        m = CFG.model
        dh = m.head_dim
        per_layer = (
            m.d_model
            + m.d_model * m.n_heads * dh
            + 2 * m.d_model * m.n_kv_heads * dh
            + m.n_heads * dh * m.d_model
            + m.d_model
            + 3 * m.d_model * m.d_ff
        )
        expect = m.vocab_size * m.d_model + m.n_layers * per_layer + m.d_model + m.d_model * m.vocab_size
        assert model.param_count(CFG) == expect

    def test_init_deterministic_and_scaled(self):
        a = get_params(7)
        b = get_params(7)
        c = get_params(8)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert any(not np.allclose(np.asarray(x), np.asarray(z)) for x, z in zip(a, c))
        d = as_dict(a)
        # output projections use the depth-scaled init
        assert np.std(np.asarray(d["wo"])) < np.std(np.asarray(d["wq"]))
        np.testing.assert_array_equal(np.asarray(d["ln_f"]), np.ones_like(d["ln_f"]))


class TestSpaEquivalence:
    """Paper §4.3: shared-prompt training is exactly per-sample training."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_loss_and_grads_match_standard(self, seed):
        rng = np.random.default_rng(seed)
        k = 3
        prompt, responses, advs = random_group(rng, CFG.model.vocab_size, lp=5, k=k, lr_max=4)
        samples = [(prompt, r, a) for r, a in zip(responses, advs)]

        pol, old, refp = get_params(1), get_params(1), get_params(2)
        seq = len(prompt) + max(len(r) for r in responses) + 1
        std_batch = build_standard(samples, rows=k, seq=seq)
        pack_len = len(prompt) + sum(len(r) for r in responses) + 2
        spa_batch = build_spa(samples, pack_len)

        g_std, m_std = run_train_step(CFG, False, pol, old, refp, std_batch)
        g_spa, m_spa = run_train_step(CFG, True, pol, old, refp, spa_batch)

        assert m_std["loss"] == pytest.approx(m_spa["loss"], rel=2e-4, abs=2e-6)
        assert m_std["kl"] == pytest.approx(m_spa["kl"], rel=2e-3, abs=1e-6)
        for name, gs, gp in zip(model.PARAM_NAMES, g_std, g_spa):
            np.testing.assert_allclose(
                np.asarray(gs), np.asarray(gp), rtol=5e-3, atol=2e-6,
                err_msg=f"grad mismatch for {name}",
            )

    def test_spa_pallas_path_matches_jnp(self):
        rng = np.random.default_rng(3)
        prompt, responses, advs = random_group(rng, CFG.model.vocab_size, lp=6, k=2, lr_max=5)
        samples = [(prompt, r, a) for r, a in zip(responses, advs)]
        total = len(prompt) + sum(len(r) for r in responses)
        pack_len = ((total + 7) // 8) * 8  # pallas wants divisible lengths
        spa_batch = build_spa(samples, pack_len)
        pol, old, refp = get_params(1), get_params(1), get_params(2)

        g_jnp, m_jnp = run_train_step(CFG, True, pol, old, refp, spa_batch)

        step_pl = model.make_train_step(CFG, spa=True, attn_impl="pallas")
        args = (
            tuple(pol) + tuple(old) + tuple(refp)
            + tuple(jnp.asarray(spa_batch[k]) for k in ("tokens", "labels", "pos", "seg", "adv", "weight"))
            + (jnp.asarray(spa_batch["prompt_len"]),)
        )
        out = step_pl(*args)
        m_pl = dict(zip(model.TRAIN_METRICS, [float(x) for x in out[N:]]))
        assert m_jnp["loss"] == pytest.approx(m_pl["loss"], rel=1e-4, abs=1e-6)
        for name, gj, gp in zip(model.PARAM_NAMES, g_jnp, out[:N]):
            np.testing.assert_allclose(
                np.asarray(gj), np.asarray(gp), rtol=5e-3, atol=2e-6,
                err_msg=f"pallas grad mismatch for {name}",
            )


class TestMicroBatching:
    """Paper Eq. 1: micro-batch gradient accumulation == full batch."""

    def test_two_micros_average_to_full_batch(self):
        rng = np.random.default_rng(5)
        samples = []
        for _ in range(4):
            prompt, responses, advs = random_group(rng, CFG.model.vocab_size, lp=4, k=1, lr_max=4)
            samples.append((prompt, responses[0], advs[0]))
        pol, old, refp = get_params(1), get_params(1), get_params(2)
        seq = 10

        full = build_standard(samples, rows=4, seq=seq)
        g_full, m_full = run_train_step(CFG, False, pol, old, refp, full)

        m1 = build_standard(samples[:2], rows=2, seq=seq)
        m2 = build_standard(samples[2:], rows=2, seq=seq)
        # standard train config is micro_bs=2; reuse cfg with rows=2
        cfg2 = tiny_test_config(**{"train.micro_bs": 2})
        g1, mm1 = run_train_step(cfg2, False, pol, old, refp, m1)
        g2, mm2 = run_train_step(cfg2, False, pol, old, refp, m2)

        assert (mm1["loss"] + mm2["loss"]) / 2 == pytest.approx(m_full["loss"], rel=1e-4)
        for name, gf, ga, gb in zip(model.PARAM_NAMES, g_full, g1, g2):
            np.testing.assert_allclose(
                np.asarray(gf),
                (np.asarray(ga) + np.asarray(gb)) / 2,
                rtol=5e-3, atol=2e-6,
                err_msg=f"micro-accum mismatch for {name}",
            )


class TestTriModel:
    def test_ratio_one_when_old_equals_policy(self):
        rng = np.random.default_rng(0)
        prompt, responses, advs = random_group(rng, CFG.model.vocab_size, lp=4, k=2, lr_max=4)
        samples = [(prompt, r, a) for r, a in zip(responses, advs)]
        batch = build_standard(samples, rows=2, seq=10)
        pol = get_params(1)
        _, metrics = run_train_step(CFG, False, pol, pol, get_params(2), batch)
        assert metrics["ratio_mean"] == pytest.approx(1.0, abs=1e-5)
        assert metrics["clip_frac"] == 0.0

    def test_kl_zero_when_ref_equals_policy(self):
        rng = np.random.default_rng(1)
        prompt, responses, advs = random_group(rng, CFG.model.vocab_size, lp=4, k=2, lr_max=4)
        samples = [(prompt, r, a) for r, a in zip(responses, advs)]
        batch = build_standard(samples, rows=2, seq=10)
        pol = get_params(1)
        _, metrics = run_train_step(CFG, False, pol, get_params(3), pol, batch)
        assert metrics["kl"] == pytest.approx(0.0, abs=1e-6)

    def test_ref_params_affect_loss_via_kl_only(self):
        rng = np.random.default_rng(2)
        prompt, responses, advs = random_group(rng, CFG.model.vocab_size, lp=4, k=2, lr_max=4)
        samples = [(prompt, r, a) for r, a in zip(responses, advs)]
        batch = build_standard(samples, rows=2, seq=10)
        pol, old = get_params(1), get_params(1)
        _, m_a = run_train_step(CFG, False, pol, old, get_params(5), batch)
        _, m_b = run_train_step(CFG, False, pol, old, get_params(6), batch)
        assert m_a["kl"] != pytest.approx(m_b["kl"], abs=1e-9)
        # surrogate part identical: loss difference equals beta * kl difference
        diff_loss = m_a["loss"] - m_b["loss"]
        diff_kl = CFG.train.kl_beta * (m_a["kl"] - m_b["kl"])
        assert diff_loss == pytest.approx(diff_kl, rel=1e-3, abs=1e-7)


class TestEngineSteps:
    """Prefill + chunked decode must agree with the training-side forward."""

    def _greedy_reference(self, p, prompt_ids, steps):
        """Greedy decode by re-running the full forward each step."""
        toks = list(prompt_ids)
        out = []
        for _ in range(steps):
            s = len(toks)
            tokens = jnp.asarray(toks, jnp.int32)[None]
            pos = jnp.arange(s, dtype=jnp.int32)[None]
            mask = kref.causal_mask(s)[None, None]
            logits = model.forward(CFG, p, tokens, pos, mask)
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            toks.append(nxt)
        return out

    def test_prefill_decode_greedy_matches_forward(self):
        flat = get_params(4)
        p = as_dict(flat)
        e = CFG.engine
        prompt_ids = [1, 5, 9, 13, 7]
        lp = len(prompt_ids)

        prefill = jax.jit(model.make_prefill(CFG))
        kv = jnp.zeros(model.kv_cache_shape(CFG), jnp.float32)
        padded = jnp.asarray(prompt_ids + [0] * (e.prompt_max - lp), jnp.int32)
        slot = jnp.asarray(1, jnp.int32)
        kv, logits = prefill(*flat, kv, slot, padded, jnp.asarray(lp, jnp.int32))
        first = int(jnp.argmax(logits))

        decode = jax.jit(model.make_decode(CFG))
        b = e.n_slots
        tok = jnp.zeros((b,), jnp.int32).at[1].set(first)
        pos = jnp.zeros((b,), jnp.int32).at[1].set(lp)
        active = jnp.zeros((b,), jnp.int32).at[1].set(1)
        generated = [first]
        for chunk in range(2):
            kv, toks, lps, pos, active = decode(
                *flat, kv, tok, pos, active,
                jnp.full((b,), chunk, jnp.int32),
                jnp.asarray(0.0, jnp.float32),  # greedy
                jnp.asarray(1.0, jnp.float32),
            )
            chunk_toks = [int(t) for t in toks[1]]
            generated.extend(chunk_toks)
            tok = toks[:, -1]
        n_steps = 1 + 2 * e.decode_chunk
        expect = self._greedy_reference(p, prompt_ids, n_steps)
        # compare until the first EOS (engine goes inactive there)
        upto = len(expect)
        if model.EOS_ID in expect:
            upto = expect.index(model.EOS_ID) + 1
        assert generated[:upto] == expect[:upto]

    def test_inactive_slots_untouched(self):
        flat = get_params(4)
        e = CFG.engine
        decode = jax.jit(model.make_decode(CFG))
        kv = jnp.zeros(model.kv_cache_shape(CFG), jnp.float32)
        b = e.n_slots
        tok = jnp.full((b,), 5, jnp.int32)
        pos = jnp.full((b,), 3, jnp.int32)
        active = jnp.zeros((b,), jnp.int32)  # nothing active
        kv2, toks, lps, pos2, act2 = decode(
            *flat, kv, tok, pos, active,
            jnp.zeros((b,), jnp.int32), jnp.asarray(1.0, jnp.float32), jnp.asarray(1.0, jnp.float32),
        )
        assert np.all(np.asarray(toks) == model.PAD_ID)
        assert np.all(np.asarray(pos2) == np.asarray(pos))
        assert np.all(np.asarray(act2) == 0)
        np.testing.assert_array_equal(np.asarray(kv2), np.asarray(kv))

    def test_eos_deactivates_midchunk(self):
        """Force EOS deterministically: zero all mixing weights so the hidden
        state is the constant token embedding, then point the lm_head at EOS
        (+1 column) and away from everything else (-1 columns)."""
        shapes = model.param_shapes(CFG)
        flat = []
        for name in model.PARAM_NAMES:
            shape = shapes[name]
            if name in ("ln1", "ln2", "ln_f"):
                flat.append(jnp.ones(shape, jnp.float32))
            elif name == "tok_emb":
                flat.append(jnp.ones(shape, jnp.float32))
            elif name == "lm_head":
                lm = -np.ones(shape, np.float32)
                lm[:, model.EOS_ID] = 1.0
                flat.append(jnp.asarray(lm))
            else:
                flat.append(jnp.zeros(shape, jnp.float32))
        flat = list(flat)
        e = CFG.engine
        decode = jax.jit(model.make_decode(CFG))
        kv = jnp.zeros(model.kv_cache_shape(CFG), jnp.float32)
        b = e.n_slots
        tok = jnp.full((b,), 5, jnp.int32)
        pos = jnp.full((b,), 2, jnp.int32)
        active = jnp.ones((b,), jnp.int32)
        _, toks, _, pos2, act2 = decode(
            *flat, kv, tok, pos, active,
            jnp.zeros((b,), jnp.int32), jnp.asarray(0.0, jnp.float32), jnp.asarray(1.0, jnp.float32),
        )
        toks = np.asarray(toks)
        assert np.all(toks[:, 0] == model.EOS_ID)
        assert np.all(toks[:, 1:] == model.PAD_ID), "post-EOS steps must emit PAD"
        assert np.all(np.asarray(act2) == 0)
        assert np.all(np.asarray(pos2) == 3), "pos advances only for the EOS step"


class TestChunkedPrefill:
    """Partial-prefix reuse: chunked prefill (any chunk size, any resume
    point) must reproduce the monolithic prefill's KV rows and last-position
    logits, so the engine can resume admission from a cached prefix."""

    def _monolithic(self, flat, prompt_ids, slot):
        e = CFG.engine
        prefill = jax.jit(model.make_prefill(CFG))
        kv = jnp.zeros(model.kv_cache_shape(CFG), jnp.float32)
        padded = jnp.asarray(
            prompt_ids + [model.PAD_ID] * (e.prompt_max - len(prompt_ids)), jnp.int32
        )
        kv, logits = prefill(
            *flat, kv, jnp.asarray(slot, jnp.int32), padded,
            jnp.asarray(len(prompt_ids), jnp.int32),
        )
        return np.asarray(kv), np.asarray(logits)

    def _chunked(self, cfg, flat, prompt_ids, slot, resume, kv_seed):
        """Run chunks of cfg.engine.cache_block from `resume` over a cache
        whose rows [0, resume) are already populated (kv_seed)."""
        chunk = jax.jit(model.make_prefill_chunk(cfg))
        cb = cfg.engine.cache_block
        kv = jnp.asarray(kv_seed)
        logits = None
        start = resume
        while start < len(prompt_ids):
            n = min(cb, len(prompt_ids) - start)
            toks = prompt_ids[start : start + n] + [model.PAD_ID] * (cb - n)
            kv, logits = chunk(
                *flat, kv, jnp.asarray(slot, jnp.int32),
                jnp.asarray(toks, jnp.int32),
                jnp.asarray(start, jnp.int32), jnp.asarray(n, jnp.int32),
            )
            start += n
        return np.asarray(kv), np.asarray(logits)

    @pytest.mark.parametrize("cache_block", [1, 2, 4, 8])
    @pytest.mark.parametrize("resume", [0, 1, 3, 6])
    def test_matches_monolithic_from_any_resume_point(self, cache_block, resume):
        cfg = tiny_test_config(**{"engine.cache_block": cache_block})
        flat = get_params(4)
        prompt_ids = [1, 5, 9, 13, 7, 11, 3]
        lp = len(prompt_ids)
        if resume >= lp:
            pytest.skip("resume past prompt end")
        slot = 1

        kv_mono, logits_mono = self._monolithic(flat, prompt_ids, slot)

        # Seed the chunked run's cache with the monolithic rows [0, resume) —
        # exactly what the engine restores from the shared-prefix cache.
        kv_seed = np.zeros_like(kv_mono)
        kv_seed[:, slot, :, :resume] = kv_mono[:, slot, :, :resume]
        kv_chunk, logits_chunk = self._chunked(cfg, flat, prompt_ids, slot, resume, kv_seed)

        np.testing.assert_allclose(
            kv_chunk[:, slot, :, :lp], kv_mono[:, slot, :, :lp],
            rtol=2e-4, atol=1e-5,
            err_msg=f"KV rows diverge (cb={cache_block}, resume={resume})",
        )
        np.testing.assert_allclose(
            logits_chunk, logits_mono, rtol=2e-4, atol=1e-5,
            err_msg=f"last-position logits diverge (cb={cache_block}, resume={resume})",
        )
        # Rows past the prompt must stay untouched (monolithic writes padded
        # junk there; chunked must not — decode owns those rows).
        assert np.all(kv_chunk[:, slot, :, lp:] == 0.0)
        # Other slots untouched.
        other = [s for s in range(CFG.engine.n_slots) if s != slot]
        assert np.all(kv_chunk[:, other] == 0.0)

    def test_chunked_then_decode_matches_monolithic_path(self):
        """End-to-end: greedy decode after chunked prefill equals greedy
        decode after monolithic prefill."""
        cfg = tiny_test_config(**{"engine.cache_block": 2})
        flat = get_params(4)
        e = cfg.engine
        prompt_ids = [1, 5, 9, 13, 7]
        lp = len(prompt_ids)
        slot = 1

        outs = []
        for which in ("mono", "chunk"):
            if which == "mono":
                kv, logits = self._monolithic(flat, prompt_ids, slot)
            else:
                kv, logits = self._chunked(
                    cfg, flat, prompt_ids, slot, 0,
                    np.zeros(model.kv_cache_shape(cfg), np.float32),
                )
            first = int(np.argmax(logits))
            decode = jax.jit(model.make_decode(cfg))
            b = e.n_slots
            tok = jnp.zeros((b,), jnp.int32).at[slot].set(first)
            pos = jnp.zeros((b,), jnp.int32).at[slot].set(lp)
            active = jnp.zeros((b,), jnp.int32).at[slot].set(1)
            kv2, toks, _, _, _ = decode(
                *flat, jnp.asarray(kv), tok, pos, active,
                jnp.zeros((b,), jnp.int32),
                jnp.asarray(0.0, jnp.float32),  # greedy
                jnp.asarray(1.0, jnp.float32),
            )
            outs.append([first] + [int(t) for t in toks[slot]])
        assert outs[0] == outs[1], f"decode diverged: {outs}"


class TestSampler:
    def test_greedy_at_zero_temperature(self):
        logits = jnp.asarray([[0.0, 3.0, 1.0], [2.0, -1.0, 0.5]])
        tok, lp = model.sample_token(
            logits, jax.random.PRNGKey(0), jnp.asarray(0.0), jnp.asarray(1.0), 0
        )
        assert [int(t) for t in tok] == [1, 0]

    def test_top_p_truncates(self):
        # one dominant token, top_p small -> always that token
        logits = jnp.asarray([[5.0, 0.0, 0.0, 0.0]])
        for seed in range(20):
            tok, _ = model.sample_token(
                logits, jax.random.PRNGKey(seed), jnp.asarray(1.0), jnp.asarray(0.5), 0
            )
            assert int(tok[0]) == 0

    def test_temperature_one_distribution(self):
        logits = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
        counts = np.zeros(3)
        for seed in range(300):
            tok, _ = model.sample_token(
                logits, jax.random.PRNGKey(seed), jnp.asarray(1.0), jnp.asarray(1.0), 0
            )
            counts[int(tok[0])] += 1
        freq = counts / counts.sum()
        np.testing.assert_allclose(freq, [0.7, 0.2, 0.1], atol=0.08)

    def test_top_k(self):
        logits = jnp.asarray([[1.0, 0.9, 0.8, -5.0]])
        for seed in range(30):
            tok, _ = model.sample_token(
                logits, jax.random.PRNGKey(seed), jnp.asarray(1.0), jnp.asarray(1.0), 2
            )
            assert int(tok[0]) in (0, 1)

    def test_top_k_keeps_all_tokens_tied_at_cutoff(self):
        # Tie rule (shared with rust/src/engine/sampler.rs): all tokens tied
        # at the k-th value stay in the support, so top_k=2 over
        # {2.0, 1.0, 1.0, 1.0} keeps four tokens and never the fifth.
        logits = jnp.asarray([[2.0, 1.0, 1.0, 1.0, -4.0]])
        seen = set()
        for seed in range(300):
            tok, _ = model.sample_token(
                logits, jax.random.PRNGKey(seed), jnp.asarray(1.0), jnp.asarray(1.0), 2
            )
            seen.add(int(tok[0]))
        assert seen == {0, 1, 2, 3}, f"cutoff ties broken: sampled {sorted(seen)}"

    def test_per_slot_sampling_independent_of_batchmates(self):
        # The placement-independence contract: a slot's token depends only on
        # its own key and logits row, not on which rows share the batch.
        row = jnp.asarray([0.3, 1.1, -0.5, 0.8, 0.0])
        key = jax.random.fold_in(jax.random.PRNGKey(1234), 7)
        outs = []
        for other in (-2.0, 3.0):  # vary the batch-mate's logits
            logits = jnp.stack([row, jnp.full((5,), other)])
            keys = jnp.stack([key, jax.random.PRNGKey(99)])
            tok, lp = model.sample_token_per_slot(
                logits, keys, jnp.asarray(1.0), jnp.asarray(0.9), 3
            )
            outs.append((int(tok[0]), float(lp[0])))
        assert outs[0] == outs[1], f"slot 0 depends on batch-mate: {outs}"


class TestAdam:
    def test_moves_against_gradient_and_clips(self):
        flat = get_params(0)
        adam = jax.jit(model.make_adam(CFG))
        grads = tuple(jnp.ones_like(p) * 100.0 for p in flat)  # huge -> clipped
        ms = tuple(jnp.zeros_like(p) for p in flat)
        vs = tuple(jnp.zeros_like(p) for p in flat)
        out = adam(*flat, *grads, *ms, *vs, jnp.asarray(0, jnp.int32))
        new_p = out[:N]
        gnorm = float(out[-1])
        total = sum(int(np.prod(p.shape)) for p in flat)
        assert gnorm == pytest.approx(100.0 * np.sqrt(total), rel=1e-5)
        for p0, p1 in zip(flat, new_p):
            diff = np.asarray(p1) - np.asarray(p0)
            assert np.all(diff < 0), "positive grads must push params down"
        # per-step magnitude bounded by ~lr (adam normalised update)
        assert np.abs(np.asarray(new_p[0]) - np.asarray(flat[0])).max() < 10 * CFG.train.lr

    def test_sft_loss_decreases(self):
        rng = np.random.default_rng(0)
        prompt = [1, 4, 5]
        resp = [6, 7, 2]
        batch = build_standard([(prompt, resp, 0.0)], rows=CFG.train.micro_bs, seq=CFG.train.seq_len)
        sft = jax.jit(model.make_sft_step(CFG))
        adam = jax.jit(model.make_adam(tiny_test_config(**{"train.lr": 0.01})))
        flat = list(get_params(0))
        ms = [jnp.zeros_like(p) for p in flat]
        vs = [jnp.zeros_like(p) for p in flat]
        losses = []
        for step in range(8):
            out = sft(
                *flat,
                jnp.asarray(batch["tokens"]),
                jnp.asarray(batch["labels"]),
                jnp.asarray(batch["pos"]),
                jnp.asarray(batch["seg"]),
                jnp.asarray(batch["weight"]),
            )
            grads, loss = out[:N], float(out[N])
            losses.append(loss)
            upd = adam(*flat, *grads, *ms, *vs, jnp.asarray(step, jnp.int32))
            flat = list(upd[:N])
            ms = list(upd[N : 2 * N])
            vs = list(upd[2 * N : 3 * N])
        assert losses[-1] < losses[0] * 0.9, f"losses {losses}"
