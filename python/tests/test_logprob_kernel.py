"""L1 correctness: fused logprob-gather kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.logprob import logprob_gather
from compile.kernels.ref import logprob_gather_ref


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([8, 32, 64, 128]),
    v=st.sampled_from([8, 32, 50]),
    scale=st.sampled_from([1.0, 10.0, 100.0]),
)
def test_matches_ref(seed, t, v, scale):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (t, v)) * scale
    labels = jax.random.randint(jax.random.fold_in(key, 1), (t,), 0, v)
    got = logprob_gather(logits, labels, block_t=min(32, t))
    want = logprob_gather_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_extreme_logits_stable():
    logits = jnp.asarray([[1e4, -1e4, 0.0, 500.0]] * 8)
    labels = jnp.asarray([0, 1, 2, 3, 0, 1, 2, 3])
    got = np.asarray(logprob_gather(logits, labels, block_t=8))
    want = np.asarray(logprob_gather_ref(logits, labels))
    assert np.isfinite(want).all()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_probabilities_normalise():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 16))
    total = 0.0
    for label in range(16):
        labels = jnp.full((4,), label)
        total += np.exp(np.asarray(logprob_gather(logits, labels, block_t=4)))
    np.testing.assert_allclose(total, np.ones(4), rtol=1e-4)
