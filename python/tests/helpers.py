"""Shared test helpers: a python mirror of the rust micro-batch builders
(rust/src/grpo/batch.rs). Keeping the packing contract duplicated here is
deliberate — the tests pin the layout both sides must agree on."""

import numpy as np

PAD_ID = 0


def build_standard(samples, rows, seq):
    """samples: list of (prompt list[int], response list[int], adv float)."""
    m = max(len(samples), 1)
    n = rows * seq
    b = {
        "tokens": np.full((rows, seq), PAD_ID, np.int32),
        "labels": np.full((rows, seq), PAD_ID, np.int32),
        "pos": np.zeros((rows, seq), np.int32),
        "seg": np.full((rows, seq), -1, np.int32),
        "adv": np.zeros((rows, seq), np.float32),
        "weight": np.zeros((rows, seq), np.float32),
        "prompt_len": np.int32(0),
    }
    for row, (prompt, response, adv) in enumerate(samples):
        lp, lr = len(prompt), len(response)
        total = lp + lr
        assert total <= seq
        b["tokens"][row, :total] = prompt + response
        b["pos"][row, :total] = np.arange(total)
        b["seg"][row, :total] = 0
        b["labels"][row, : total - 1] = b["tokens"][row, 1:total]
        if lr > 0 and lp > 0:
            w = 1.0 / (m * lr)
            b["weight"][row, lp - 1 : lp + lr - 1] = w
            b["adv"][row, lp - 1 : lp + lr - 1] = adv
    return b


def build_spa(samples, pack_len):
    """One group, shared prompt; mirrors rust build_spa exactly."""
    prompt = samples[0][0]
    lp = len(prompt)
    k = len(samples)
    b = {
        "tokens": np.full((1, pack_len), PAD_ID, np.int32),
        "labels": np.full((1, pack_len), PAD_ID, np.int32),
        "pos": np.zeros((1, pack_len), np.int32),
        "seg": np.full((1, pack_len), -1, np.int32),
        "adv": np.zeros((1, pack_len), np.float32),
        "weight": np.zeros((1, pack_len), np.float32),
        "prompt_len": np.int32(lp),
    }
    b["tokens"][0, :lp] = prompt
    b["pos"][0, :lp] = np.arange(lp)
    b["seg"][0, :lp] = 0
    cursor = lp
    for s_idx, (p, response, adv) in enumerate(samples):
        assert p == prompt
        lr = len(response)
        if lr == 0:
            continue
        w = 1.0 / (k * lr)
        for i in range(lr):
            idx = cursor + i
            b["tokens"][0, idx] = prompt[-1] if i == 0 else response[i - 1]
            b["pos"][0, idx] = lp - 1 + i
            b["seg"][0, idx] = s_idx + 1
            b["labels"][0, idx] = response[i]
            b["weight"][0, idx] = w
            b["adv"][0, idx] = adv
        cursor += lr
    assert cursor <= pack_len
    return b


def random_group(rng, vocab, lp, k, lr_max):
    """A random (prompt, responses, advs) group avoiding special ids 0..2."""
    prompt = [int(x) for x in rng.integers(3, vocab, lp)]
    responses = [
        [int(x) for x in rng.integers(3, vocab, rng.integers(1, lr_max + 1))] for _ in range(k)
    ]
    advs = [float(a) for a in rng.normal(size=k)]
    return prompt, responses, advs
