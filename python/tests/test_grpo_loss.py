"""GRPO objective vs a hand-written numpy oracle, plus analytic edge cases."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.config import tiny_test_config

CFG = tiny_test_config()


def numpy_grpo(lp_pol, lp_old, lp_ref, adv, weight, beta, el, eh):
    ratio = np.exp(lp_pol - lp_old)
    clipped = np.clip(ratio, 1 - el, 1 + eh)
    surr = np.minimum(ratio * adv, clipped * adv)
    lrr = lp_ref - lp_pol
    kl = np.exp(lrr) - lrr - 1
    return -np.sum(weight * (surr - beta * kl))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([0.1, 1.0, 3.0]))
def test_matches_numpy(seed, scale):
    rng = np.random.default_rng(seed)
    shape = (2, 6)
    lp_pol = rng.normal(-2, scale, shape).astype(np.float32)
    lp_old = lp_pol + rng.normal(0, 0.3, shape).astype(np.float32)
    lp_ref = lp_pol + rng.normal(0, 0.3, shape).astype(np.float32)
    adv = rng.normal(0, 1, shape).astype(np.float32)
    weight = rng.uniform(0, 1, shape).astype(np.float32)
    weight /= weight.sum()

    t = CFG.train
    want = numpy_grpo(lp_pol, lp_old, lp_ref, adv, weight, t.kl_beta, t.clip_eps_low, t.clip_eps_high)

    logits_dummy = jnp.zeros(shape + (4,), jnp.float32)
    loss, metrics = model.grpo_objective(
        CFG,
        jnp.asarray(lp_pol), jnp.asarray(lp_old), jnp.asarray(lp_ref),
        jnp.asarray(adv), jnp.asarray(weight), logits_dummy,
    )
    assert float(loss) == pytest.approx(float(want), rel=1e-4, abs=1e-6)
    # kl metric is the weighted k3 estimator
    lrr = lp_ref - lp_pol
    kl = np.exp(lrr) - lrr - 1
    assert float(metrics["kl"]) == pytest.approx(float(np.sum(weight * kl)), rel=1e-4, abs=1e-6)


def test_identical_policies_loss_is_zero_advantage_term():
    """lp_pol == lp_old == lp_ref -> ratio 1, kl 0 -> loss = -sum(w * adv)."""
    shape = (1, 5)
    lp = np.full(shape, -1.3, np.float32)
    adv = np.asarray([[1.0, -1.0, 0.5, 0.0, 2.0]], np.float32)
    w = np.full(shape, 0.2, np.float32)
    loss, metrics = model.grpo_objective(
        CFG, jnp.asarray(lp), jnp.asarray(lp), jnp.asarray(lp),
        jnp.asarray(adv), jnp.asarray(w), jnp.zeros(shape + (3,)),
    )
    assert float(loss) == pytest.approx(-float(np.sum(w * adv)), rel=1e-5)
    assert float(metrics["kl"]) == pytest.approx(0.0, abs=1e-7)
    assert float(metrics["clip_frac"]) == 0.0
    assert float(metrics["ratio_mean"]) == pytest.approx(1.0, abs=1e-6)


def test_clipping_engages_for_large_ratios():
    shape = (1, 2)
    lp_pol = np.asarray([[0.0, 0.0]], np.float32)
    lp_old = np.asarray([[-2.0, 2.0]], np.float32)  # ratios e^2, e^-2
    adv = np.ones(shape, np.float32)
    w = np.full(shape, 0.5, np.float32)
    _, metrics = model.grpo_objective(
        CFG, jnp.asarray(lp_pol), jnp.asarray(lp_old), jnp.asarray(lp_pol),
        jnp.asarray(adv), jnp.asarray(w), jnp.zeros(shape + (3,)),
    )
    assert float(metrics["clip_frac"]) == pytest.approx(1.0)


def test_kl_k3_nonnegative():
    rng = np.random.default_rng(0)
    shape = (4, 8)
    lp_pol = rng.normal(-2, 1, shape).astype(np.float32)
    lp_ref = rng.normal(-2, 1, shape).astype(np.float32)
    w = np.full(shape, 1.0 / 32, np.float32)
    _, metrics = model.grpo_objective(
        CFG, jnp.asarray(lp_pol), jnp.asarray(lp_pol), jnp.asarray(lp_ref),
        jnp.zeros(shape, jnp.float32), jnp.asarray(w), jnp.zeros(shape + (3,)),
    )
    assert float(metrics["kl"]) >= 0.0
